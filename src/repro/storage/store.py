"""``Hercules`` — one handle for the whole index lifecycle.

The paper's index is a long-lived disk artifact that must absorb inserts,
not a one-shot build. This module is the store facade over
``repro.storage``: one object owns creation, incremental ingest, compaction,
and query serving for an index directory::

    from repro import api

    with api.Hercules.create("idx/", config, data=chunks_a) as hx:
        hx.append(chunks_b)          # journal segment; atomic manifest commit
        hx.query(queries, k=5)       # exact: base index + journal merge
        hx.compact()                 # replay journal through the chunked
                                     # build; bit-identical to a from-scratch
                                     # build over A concat B
        hx.engine("ooc-local").knn(queries)

Append discipline (the paper's insert workload; ParIS+'s append-without-
rewriting organization):

* ``append`` lands new rows in **journal segments** (raw LRD rows + iSAX
  LSD sidecar, original append order, each file CRC-checksummed). The base
  files are never touched; the atomic manifest ``os.replace`` is the single
  commit point, so a crash between segment write and manifest commit leaves
  uncommitted orphans that the next writable ``open`` sweeps away.
* ``query`` stays **exact** with a pending journal: the base backend
  answers as usual and journal rows are merged in with the same
  difference-form squared-ED arithmetic every backend uses.
* ``compact`` replays base + journal rows through the *existing* chunked
  build primitives (``_round_stats``/``_route_members`` via
  ``build_tree_chunked``; ``assemble_layout`` geometry via
  ``stream_base_files``) into a new file **generation**, then republishes
  the manifest atomically. Because the chunked build is bit-identical to
  the one-shot build for any chunking, append+compact over A then B equals
  a from-scratch build over A concat B, bit for bit.

Engines handed out by :meth:`Hercules.engine` are cached per configuration;
``append``/``compact`` invalidate every cached compiled plan
(:meth:`repro.core.engine.QueryEngine.invalidate`) and re-resolve backends
against the new store state, so a stale plan can never serve a mutated
collection.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S
from repro.core.engine import (QueryEngine, make_disk_backend,
                               resolve_backend_name)
from repro.core.index import HerculesIndex, IndexConfig
from repro.core.search import INF, KnnResult, SearchConfig
from repro.data.pipeline import ChunkSource, _ChunkedBase, iter_chunks
from repro.storage.build import build_index_to_disk, stream_base_files
from repro.storage.format import (LAYOUT_STATIC_FIELDS, MANIFEST_FILE,
                                  IndexFormatError, SavedIndex, _file_entry,
                                  generation_of, has_base, journal_of,
                                  open_saved, read_manifest, save_index,
                                  segment_file_names, verify_files,
                                  write_manifest, JOURNAL_DIR)

# files a crashed (uncommitted) mutation may leave behind; anything matching
# that the manifest does not reference is swept by a writable open
_ORPHAN_BASE_RE = re.compile(
    r"^(?:tree|layout)(?:-\d{5})?\.npz$|^(?:lrd|lsd|enc)(?:-\d{5})?\.npy$"
    r"|^manifest\.json\.tmp$")
_ORPHAN_SEG_RE = re.compile(r"^seg-\d{5}\.(?:lrd|lsd)\.npy$")

_EMPTY_STATICS = {k: 0 for k in LAYOUT_STATIC_FIELDS}


def _as_source(data, chunk_size: int) -> ChunkSource:
    if all(hasattr(data, a) for a in ("chunk", "num_chunks", "num_series")):
        return data                                  # already a ChunkSource
    arr = np.asarray(data, np.float32)
    if arr.ndim != 2:
        raise ValueError(f"expected a 2-D series collection, got {arr.shape}")
    return _ChunkedBase(arr, chunk_size)


class _ConcatRows:
    """Row-sliceable view over base rows (original id order, gathered lazily
    from the LRD memmap) followed by journal segments (append order) — the
    compaction replay source. Reads only the rows a slice asks for."""

    def __init__(self, parts: list):
        self._parts = parts               # row-sliceable, shape (rows, n)
        self._offsets = np.cumsum([0] + [int(p.shape[0]) for p in parts])
        self.shape = (int(self._offsets[-1]), int(parts[0].shape[1]))

    def __getitem__(self, sl: slice) -> np.ndarray:
        lo, hi, step = sl.indices(self.shape[0])
        assert step == 1
        out = []
        for part, off in zip(self._parts, self._offsets[:-1]):
            p_lo = max(lo - off, 0)
            p_hi = min(hi - off, int(part.shape[0]))
            if p_lo < p_hi:
                out.append(np.asarray(part[p_lo:p_hi], np.float32))
        return out[0] if len(out) == 1 else np.concatenate(out, axis=0)


class _BaseRows:
    """Original-id-order view of a SavedIndex's LRD memmap (rows permuted
    back through ``inv_perm``; fancy indexing reads only the sliced rows)."""

    def __init__(self, saved: SavedIndex):
        self._saved = saved
        self._inv_perm = np.asarray(saved.small["inv_perm"])
        self.shape = (saved.num_series, saved.series_len)

    def __getitem__(self, sl: slice) -> np.ndarray:
        return self._saved._mapped("lrd")[self._inv_perm[sl]]


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_triplet(d0, p0, i0, d1, p1, i1, *, k: int):
    """Per-query merge of (dists, positions, ids) candidate sets into the
    running top-k. Ties break toward the earlier array — base results before
    journal rows, matching a from-scratch scan's id-order visit."""

    def one(args):
        a_d, a_p, a_i, b_d, b_p, b_i = args
        d = jnp.concatenate([a_d, b_d])
        p = jnp.concatenate([a_p, b_p])
        i = jnp.concatenate([a_i, b_i])
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, p[idx], i[idx]

    return jax.lax.map(one, (d0, p0, i0, d1, p1, i1))


@jax.jit
def _journal_block_dists(rows: jax.Array, q: jax.Array) -> jax.Array:
    """(Q, B) difference-form squared ED — the same arithmetic as every
    exact backend path, so merged answers stay bit-identical."""
    return jnp.sum(jnp.square(rows[None, :, :] - q[:, None, :]), axis=-1)


class Hercules:
    """A Hercules store: one index directory, one handle, whole lifecycle.

    Modes: ``"r"`` (read/serve only) and ``"a"`` (append/compact allowed;
    also sweeps uncommitted orphan files left by a crashed mutation).
    Context-managed — ``close()`` releases the base memmaps and drops every
    cached engine.
    """

    def __init__(self, path: str, mode: str, manifest: dict):
        if mode not in ("r", "a"):
            raise ValueError(f"mode must be 'r' or 'a', got {mode!r}")
        self.path = path
        self.mode = mode
        self.manifest = manifest
        self.recovered: list[str] = []
        if mode == "a":
            self.recovered = self._sweep_orphans()
        self.saved: SavedIndex | None = (
            open_saved(path, manifest) if has_base(manifest) else None)
        self._engines: dict[Any, QueryEngine] = {}
        self._data_version = 0
        self._closed = False

    # -- lifecycle ----------------------------------------------------------

    @classmethod
    def create(cls, path: str, config: IndexConfig | None = None, *,
               data=None, chunk_size: int = 8192, overwrite: bool = False,
               extra_meta: dict | None = None,
               codec: str = "raw") -> "Hercules":
        """Create a store at ``path`` (mode ``"a"``). With ``data`` (an
        array or :class:`ChunkSource`) the base index is built immediately
        via the chunked streaming builder; without it the store starts
        empty and the first ``append`` + ``compact`` builds the base.
        ``codec`` selects the leaf codec for the base files (see
        ``repro.storage.codecs``); answers stay bit-identical under every
        codec — lossy codecs only shrink the streamed bytes."""
        from repro.storage.codecs import get_codec

        get_codec(codec)  # validate before touching the directory
        config = config or IndexConfig()
        mf = os.path.join(path, MANIFEST_FILE)
        if os.path.exists(mf):
            if not overwrite:
                raise IndexFormatError(
                    f"{path!r} already holds an index (pass overwrite=True "
                    f"to replace it, or Hercules.open(path, 'a') to extend)")
            os.remove(mf)
        os.makedirs(path, exist_ok=True)
        if data is None:
            write_manifest(path, config, 0, _EMPTY_STATICS, extra=extra_meta,
                           base=False, codec=codec)
        else:
            build_index_to_disk(_as_source(data, chunk_size), path, config,
                                extra_meta=extra_meta, codec=codec)
        return cls.open(path, "a")

    @classmethod
    def open(cls, path: str, mode: str = "r",
             verify: bool = True) -> "Hercules":
        """Open an existing store. Version-1 directories open unchanged (no
        journal); their first ``append`` migrates the manifest to v2."""
        manifest = read_manifest(path)
        if verify:
            verify_files(path, manifest)
        return cls(path, mode, manifest)

    @classmethod
    def from_index(cls, path: str, index: HerculesIndex,
                   extra_meta: dict | None = None) -> "Hercules":
        """Persist an in-memory :class:`HerculesIndex` and return the live
        store handle (the ``save_index`` successor)."""
        save_index(index, path, extra_meta=extra_meta)
        return cls.open(path, "a")

    def close(self) -> None:
        """Release the base memmaps and drop cached engines. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._engines.clear()
        if self.saved is not None:
            self.saved.close()

    def __enter__(self) -> "Hercules":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection ------------------------------------------------------

    @property
    def config(self) -> IndexConfig:
        from repro.storage.format import _restore_config
        return _restore_config(self.manifest)

    @property
    def journal(self) -> dict:
        return journal_of(self.manifest)

    @property
    def generation(self) -> int:
        return generation_of(self.manifest)

    @property
    def base_rows(self) -> int:
        return self.saved.num_series if self.saved is not None else 0

    @property
    def pending_rows(self) -> int:
        """Rows appended since the last compaction (journal-resident)."""
        return self.journal["rows"]

    @property
    def num_series(self) -> int:
        return self.base_rows + self.pending_rows

    @property
    def series_len(self) -> int | None:
        if self.saved is not None:
            return self.saved.series_len
        segs = self.journal["segments"]
        return int(segs[0]["series_len"]) if segs else None

    @property
    def codec(self) -> str:
        """Leaf codec of the committed base files (``"raw"`` for v1/v2
        indexes and empty stores). Change it with ``compact(codec=...)``."""
        from repro.storage.format import codec_of
        return codec_of(self.manifest)

    @property
    def data_version(self) -> int:
        """Bumped by every append/compact — the plan-invalidation epoch."""
        return self._data_version

    def index(self) -> HerculesIndex:
        """Materialize the base as an in-memory index (``load_index``
        successor). Refuses while journal rows are pending — compact first
        so the materialization cannot silently drop appended rows."""
        self._require_open()
        if self.saved is None:
            raise IndexFormatError(f"{self.path!r}: store has no base index")
        if self.pending_rows:
            raise IndexFormatError(
                f"{self.path!r}: {self.pending_rows} journal rows pending — "
                f"compact() before materializing the index")
        return self.saved.to_index()

    def describe(self) -> dict:
        return {
            "path": self.path,
            "mode": self.mode,
            "generation": self.generation,
            "base_rows": self.base_rows,
            "pending_rows": self.pending_rows,
            "journal_segments": len(self.journal["segments"]),
            "series_len": self.series_len,
            "codec": self.codec,
            "data_version": self._data_version,
            "cached_engines": len(self._engines),
        }

    # -- guards -------------------------------------------------------------

    def _require_open(self) -> None:
        if self._closed:
            raise IndexFormatError(f"{self.path!r}: store handle is closed")

    def _require_writable(self) -> None:
        self._require_open()
        if self.mode != "a":
            raise IndexFormatError(
                f"{self.path!r} is open read-only; Hercules.open(path, 'a') "
                f"to append or compact")

    # -- crash recovery -----------------------------------------------------

    def _sweep_orphans(self) -> list[str]:
        """Delete files a crashed mutation left uncommitted (present on disk
        but unreferenced by the manifest). Safe because the manifest commit
        is atomic: anything it does not name was never part of the store."""
        keep = set()
        for name, entry in self.manifest.get("files", {}).items():
            keep.add(entry.get("path", name))
        for seg in journal_of(self.manifest)["segments"]:
            keep.update(seg.get("files", {}))
        removed = []
        for fn in sorted(os.listdir(self.path)):
            if fn in keep or not _ORPHAN_BASE_RE.match(fn):
                continue
            os.remove(os.path.join(self.path, fn))
            removed.append(fn)
        jdir = os.path.join(self.path, JOURNAL_DIR)
        if os.path.isdir(jdir):
            for fn in sorted(os.listdir(jdir)):
                rel = f"{JOURNAL_DIR}/{fn}"
                if rel in keep or not _ORPHAN_SEG_RE.match(fn):
                    continue
                os.remove(os.path.join(jdir, fn))
                removed.append(rel)
        return removed

    # -- ingest -------------------------------------------------------------

    def append(self, data, *, chunk_size: int = 8192,
               provenance: dict | None = None) -> dict:
        """Append rows as one journal segment; returns the segment record.

        The segment's LRD rows (original append order) and iSAX LSD sidecar
        are written and checksummed first; the atomic manifest republish is
        the commit. Appended rows take original ids following the existing
        collection (base then journal order), are immediately visible to
        :meth:`query` (exact journal merge), and fold into the base at the
        next :meth:`compact`. Cached engine plans are invalidated.
        """
        self._require_writable()
        source = _as_source(data, chunk_size)
        if source.num_series <= 0:
            raise ValueError("append needs at least one row")
        config = self.config
        n = source.series_len
        expect = self.series_len
        if expect is not None and n != expect:
            raise ValueError(f"appended series length {n} != store series "
                             f"length {expect}")
        if n % config.sax_segments:
            raise ValueError(f"series length {n} must be divisible by "
                             f"{config.sax_segments} iSAX segments")

        journal = self.journal
        seg_id = len(journal["segments"])
        lrd_rel, lsd_rel = segment_file_names(seg_id)
        os.makedirs(os.path.join(self.path, JOURNAL_DIR), exist_ok=True)
        t0 = time.perf_counter()
        lrd = np.lib.format.open_memmap(
            os.path.join(self.path, lrd_rel), mode="w+", dtype=np.float32,
            shape=(source.num_series, n))
        lsd = np.lib.format.open_memmap(
            os.path.join(self.path, lsd_rel), mode="w+", dtype=np.uint8,
            shape=(source.num_series, config.sax_segments))
        for start, chunk in iter_chunks(source):
            lrd[start:start + chunk.shape[0]] = chunk
            lsd[start:start + chunk.shape[0]] = np.asarray(
                S.isax(jnp.array(chunk, copy=True), config.sax_segments))
        lrd.flush()
        lsd.flush()
        del lrd, lsd

        segment = {
            "name": f"seg-{seg_id:05d}",
            "rows": int(source.num_series),
            "series_len": int(n),
            "files": {
                lrd_rel: _file_entry(os.path.join(self.path, lrd_rel)),
                lsd_rel: _file_entry(os.path.join(self.path, lsd_rel)),
            },
        }
        journal["segments"].append(segment)
        journal["rows"] += segment["rows"]
        extra = self._extra_with_provenance(provenance)
        extra["append"] = {
            "last_rows": segment["rows"],
            "seconds": round(time.perf_counter() - t0, 4),
        }
        self.manifest = write_manifest(
            self.path, config, int(self.manifest.get("max_depth", 0)),
            self.manifest.get("layout_static", _EMPTY_STATICS), extra=extra,
            entries=self.manifest.get("files", {}), journal=journal,
            generation=self.generation, base=has_base(self.manifest),
            codec=self.codec)
        self._invalidate_engines()
        return segment

    def compact(self, chunk_size: int = 8192,
                prefetch: str | None = None,
                codec: str | None = None) -> dict:
        """Fold every journal segment into a new base-file generation.

        Replays base rows (original id order) followed by journal rows
        through the same chunked-build primitives as a from-scratch
        streaming build — leaf splits, LRD reordering, synopsis passes —
        so the compacted index is **bit-identical** to building once over
        the concatenated collection. The old generation stays valid until
        the atomic manifest commit; its files and the journal segments are
        swept afterwards. No-op when the journal is empty (unless
        ``codec`` asks for a migration). Returns the manifest.

        ``codec`` re-encodes the new generation under a different leaf
        codec (``None`` keeps the store's current codec) — the v2→v3 (or
        codec→codec) migration path. Since the base files are rewritten
        anyway, a codec switch costs nothing extra.
        """
        self._require_writable()
        if codec is not None:
            from repro.storage.codecs import get_codec
            get_codec(codec)  # validate before any I/O
        journal = self.journal
        target_codec = self.codec if codec is None else codec
        if not journal["segments"] and (target_codec == self.codec
                                        or self.saved is None):
            return self.manifest
        config = self.config
        parts: list = []
        if self.saved is not None:
            parts.append(_BaseRows(self.saved))
        seg_maps = []
        for seg in journal["segments"]:
            lrd_rel = next(f for f in seg["files"] if f.endswith(".lrd.npy"))
            seg_maps.append(np.load(os.path.join(self.path, lrd_rel),
                                    mmap_mode="r"))
        parts.extend(seg_maps)
        source = _ChunkedBase(_ConcatRows(parts), chunk_size)

        gen = self.generation + 1
        t0 = time.perf_counter()
        names, statics, max_depth, timings = stream_base_files(
            source, self.path, config, generation=gen, prefetch=prefetch,
            codec=target_codec)
        extra = self._extra_with_provenance(None)
        extra["build"] = timings
        extra["compact"] = {
            "generation": gen,
            "journal_rows": journal["rows"],
            "segments": len(journal["segments"]),
            "codec": target_codec,
            "seconds": round(time.perf_counter() - t0, 4),
        }
        extra.pop("append", None)
        manifest = write_manifest(
            self.path, config, max_depth, statics, extra=extra, files=names,
            journal=None, generation=gen, base=True,      # <- commit point
            codec=target_codec)
        del seg_maps, source, parts

        old = self.saved
        self.manifest = manifest
        if old is not None:
            # loud staleness: anything still holding the pre-compact handle
            # raises instead of silently serving the old collection. Closed
            # *before* the sweep — platforms that refuse to unlink mapped
            # files would otherwise fail deleting the old generation.
            old.close()
        self.recovered = self._sweep_orphans()   # old generation + journal
        self.saved = open_saved(self.path, manifest)
        self._invalidate_engines()
        return manifest

    def _extra_with_provenance(self, provenance: dict | None) -> dict:
        extra = dict(self.manifest.get("extra", {}))
        if provenance is not None:
            old = extra.get("data")
            if old is None:
                extra["data"] = provenance
            elif old.get("kind") == "concat":
                extra["data"] = {"kind": "concat",
                                 "parts": [*old["parts"], provenance]}
            else:
                extra["data"] = {"kind": "concat", "parts": [old, provenance]}
        return extra

    # -- serving ------------------------------------------------------------

    def engine(self, backend: str = "local", *,
               search: SearchConfig | None = None,
               memory_budget_mb: float = 64.0,
               engine_config=None,
               prefetch: str | None = None,
               shards: int | None = None) -> QueryEngine:
        """A :class:`QueryEngine` over the base index, cached per
        configuration. Serves the **base** only — use :meth:`query` to also
        see journal rows pending compaction. ``append``/``compact``
        invalidate every cached plan and re-resolve the backend against the
        new store state on the next call. ``prefetch`` overrides
        ``SearchConfig.prefetch`` for the ooc backends (``"thread"`` = async
        reader + two-slot host buffer; answers bit-identical). ``shards``
        picks the mesh size for ``backend="dist-ooc"`` (default: one shard
        per visible device; the budget then applies per shard)."""
        self._require_open()
        if self.saved is None:
            raise IndexFormatError(
                f"{self.path!r}: store has no base index yet — append then "
                f"compact() before serving")
        # validate the name *before* it enters the cache key, so unknown
        # names fail with the registry's canonical message instead of being
        # cached and re-raised from construction on every call
        spec = resolve_backend_name(backend, kind="disk")
        if prefetch is not None:
            search = dataclasses.replace(search or self.config.search,
                                         prefetch=prefetch)
        # the budget only parameterizes the streaming (ooc/dist) backends —
        # keep it out of the key otherwise, so budget variants don't
        # duplicate an already fully materialized local/scan backend
        streams = "ooc" in spec.name
        budget = float(memory_budget_mb) if streams else None
        key = (backend, search, budget, engine_config,
               shards if backend == "dist-ooc" else None)
        eng = self._engines.get(key)
        if eng is None:
            be = make_disk_backend(backend, self, search=search,
                                   memory_budget_mb=memory_budget_mb,
                                   shards=shards)
            eng = QueryEngine(be, engine_config)
            self._engines[key] = eng
        return eng

    def query(self, queries, k: int | None = None, *,
              backend: str = "local", search: SearchConfig | None = None,
              memory_budget_mb: float = 64.0, shards: int | None = None,
              **overrides: Any) -> KnnResult:
        """Exact kNN over the *whole* store: base index via the named
        backend plus an exact merge of any journal rows still pending
        compaction (same difference-form arithmetic, ids continuing the
        collection)."""
        self._require_open()
        q = jnp.asarray(queries)
        if q.ndim == 1:
            q = q[None, :]
        if self.saved is None:
            return self._journal_only_knn(q, k, search, overrides)
        eng = self.engine(backend, search=search,
                          memory_budget_mb=memory_budget_mb, shards=shards)
        res = eng.knn(q, k=k, **overrides)
        if self.pending_rows:
            res = self._merge_journal(res, q, res.dists.shape[1])
        return res

    def _journal_rows(self) -> list[np.ndarray]:
        segs = self.journal["segments"]
        parts = []
        for seg in segs:
            lrd_rel = next(f for f in seg["files"] if f.endswith(".lrd.npy"))
            parts.append(np.load(os.path.join(self.path, lrd_rel),
                                 mmap_mode="r"))
        return parts

    def _resolve_k(self, k: int | None, search: SearchConfig | None,
                   overrides: dict) -> int:
        if k is not None:
            return k
        if "k" in overrides:
            return overrides["k"]
        return (search or self.config.search).k

    def _journal_only_knn(self, q: jax.Array, k: int | None,
                          search: SearchConfig | None,
                          overrides: dict) -> KnnResult:
        if not self.pending_rows:
            raise IndexFormatError(
                f"{self.path!r}: store is empty — nothing to query")
        kk = self._resolve_k(k, search, overrides)
        qn = q.shape[0]
        d0 = jnp.full((qn, kk), INF)
        p0 = jnp.full((qn, kk), -1, jnp.int32)
        base = KnnResult(
            dists=d0, positions=p0, ids=p0,
            path=jnp.full((qn,), 3, jnp.int32),
            eapca_pr=jnp.zeros((qn,), jnp.float32),
            sax_pr=jnp.zeros((qn,), jnp.float32),
            accessed=jnp.zeros((qn,), jnp.int32),
            visited_leaves=jnp.zeros((qn,), jnp.int32))
        return self._merge_journal(base, q, kk)

    def _merge_journal(self, res: KnnResult, q: jax.Array, k: int,
                       block: int = 4096) -> KnnResult:
        """Fold journal rows into a base result — blocked difference-form
        scan, positions -1 (journal rows have no layout position yet)."""
        d, p, i = res.dists, res.positions, res.ids
        offset = self.base_rows
        accessed = res.accessed
        for seg_rows in self._journal_rows():
            rows = np.asarray(seg_rows)
            for lo in range(0, rows.shape[0], block):
                # rows is an mmap view (journal segments stay on disk); the
                # device block must own its bytes or closing the store
                # invalidates in-flight distance computations
                blk = jnp.array(rows[lo:lo + block], copy=True)
                db = _journal_block_dists(blk, q)              # (Q, B)
                ids = offset + lo + jnp.arange(blk.shape[0], dtype=jnp.int32)
                ib = jnp.broadcast_to(ids, db.shape)
                pb = jnp.full(db.shape, -1, jnp.int32)
                d, p, i = _merge_triplet(d, p, i, db, pb, ib, k=k)
            offset += rows.shape[0]
            accessed = accessed + jnp.int32(rows.shape[0])
        return res._replace(dists=d, positions=p, ids=i, accessed=accessed)

    def _invalidate_engines(self) -> None:
        self._data_version += 1
        for eng in self._engines.values():
            eng.invalidate()
        self._engines.clear()
