"""Pluggable leaf-compression codecs for the on-disk LRD hot path.

Format v3 indexes may carry, next to the raw float32 ``lrd.npy``, an
*encoded* sidecar (``enc.npy``) holding one fixed-width ``uint8`` row per
series.  Out-of-core backends stream the encoded rows instead of the raw
ones (fewer bytes off disk), decode them on device, and use the decoded
values only to *select* candidates; reported answers are always re-checked
against the full-precision rows, so every codec — lossy or not — yields
answers bit-identical to ``LocalBackend``.

A codec is a frozen dataclass registered by name:

``encode(block)``
    host-side: ``(B, n) float32 -> (B, row_bytes(n)) uint8``.  For lossy
    codecs the encoded row *embeds* a per-row reconstruction-error bound
    ``e >= ||s - decode(encode(s))||_2`` (computed in float64 and inflated)
    so the engine can turn approximate distances into sound lower/upper
    bounds without touching the raw rows.
``decode(enc, series_len)``
    device-side (jit-traceable): ``(B, W) uint8 -> ((B, n) float32 rows,
    (B,) float32 err)``.  The output is a fresh on-device buffer — it never
    aliases the reader slot the encoded bytes arrived in (this is the
    ``decode`` cleanse herculint's alias-transfer rule knows about).
``exact``
    whether ``decode(encode(x)) == x`` bit-for-bit (then ``err == 0``).

Use :func:`register_codec` to add codecs; :func:`list_codecs` enumerates
the registry and :func:`get_codec` resolves a validated name.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import summaries as S

__all__ = [
    "Codec",
    "RawCodec",
    "Bf16Codec",
    "SaxResidualCodec",
    "register_codec",
    "get_codec",
    "list_codecs",
    "CODEC_CHOICES",
    "sax_segments_for",
]

# Error bounds are computed in float64 and inflated by this relative margin
# before being narrowed to float32, so the stored bound stays sound even
# after the narrowing and the engine's float32 bound arithmetic.
_ERR_INFLATE = 1.0 + 1e-6


@runtime_checkable
class Codec(Protocol):
    """Protocol for leaf codecs (see module docstring for the contract)."""

    name: str
    exact: bool

    def row_bytes(self, series_len: int) -> int:
        """Encoded bytes per series (0 => no sidecar; stream raw rows)."""

    def encode(self, block: np.ndarray) -> np.ndarray:
        """Host: ``(B, n) float32 -> (B, row_bytes(n)) uint8``."""

    def decode(self, enc, series_len: int):
        """Device (traceable): ``(B, W) uint8 -> (rows f32, err f32)``."""


def _err_bound(block: np.ndarray, decoded: np.ndarray) -> np.ndarray:
    """Sound per-row float32 upper bound on ``||row - decoded_row||_2``.

    ``decoded`` is one float32 evaluation of the decode arithmetic; other
    evaluations (e.g. XLA fusing mul+add into fma inside a larger jit) may
    differ by ~1 ulp per element, so on top of the measured error we add an
    analytic re-association margin proportional to the row norms.
    """
    b64 = block.astype(np.float64)
    d64 = decoded.astype(np.float64)
    diff = b64 - d64
    margin = (np.sqrt(np.sum(d64 * d64, axis=1))
              + np.sqrt(np.sum(b64 * b64, axis=1))) * 2.0 ** -21 + 1e-6
    err = (np.sqrt(np.sum(diff * diff, axis=1)) + margin) * _ERR_INFLATE
    err32 = err.astype(np.float32)
    # float64 -> float32 narrowing may round down; bump one ulp to stay sound.
    return np.where(err32.astype(np.float64) < err,
                    np.nextafter(err32, np.float32(np.inf)), err32)


@dataclasses.dataclass(frozen=True)
class RawCodec:
    """Identity codec: rows are the float32 bytes themselves (v2 behaviour).

    ``row_bytes`` is the raw width, but no ``enc.npy`` sidecar is written —
    the engine streams ``lrd.npy`` directly, exactly as in format v2.
    """

    name: str = "raw"
    exact: bool = True

    def row_bytes(self, series_len: int) -> int:
        return 4 * series_len

    def encode(self, block: np.ndarray) -> np.ndarray:
        rows = np.ascontiguousarray(block, dtype=np.float32)
        return rows.view(np.uint8).reshape(rows.shape[0], -1)

    def decode(self, enc, series_len: int):
        raw = jnp.reshape(enc, (enc.shape[0], series_len, 4))
        rows = jax.lax.bitcast_convert_type(raw, jnp.float32)
        return rows, jnp.zeros((enc.shape[0],), jnp.float32)


@dataclasses.dataclass(frozen=True)
class Bf16Codec:
    """bfloat16 rows + an embedded float32 error bound.

    Row layout (``W = 2n + 4`` bytes, ~51% of raw for n >= 32)::

        [ 2n bytes : bfloat16 values ][ 4 bytes : float32 err bound ]

    bfloat16 truncates the float32 mantissa, so ``decode`` is a widening
    (exact) upcast of an inexact narrowing: the engine needs the stored
    ``err`` to bound true distances.  The payload prefix is bit-castable
    straight to ``bfloat16`` on device, which is what the fused
    ``decode_bf16_ed_matrix`` kernel exploits.
    """

    name: str = "bf16"
    exact: bool = False

    def row_bytes(self, series_len: int) -> int:
        return 2 * series_len + 4

    def encode(self, block: np.ndarray) -> np.ndarray:
        rows = np.ascontiguousarray(block, dtype=np.float32)
        half = rows.astype(jnp.bfloat16)  # round-to-nearest-even
        err = _err_bound(rows, half.astype(np.float32))
        out = np.empty((rows.shape[0], self.row_bytes(rows.shape[1])),
                       dtype=np.uint8)
        out[:, :-4] = half.view(np.uint8)
        out[:, -4:] = err.view(np.uint8).reshape(-1, 4)
        return out

    @staticmethod
    def split(enc):
        """Traceable: ``(B, W) uint8 -> ((B, 2n) payload, (B,) err)``."""
        payload = enc[:, :-4]
        err = jax.lax.bitcast_convert_type(
            jnp.reshape(enc[:, -4:], (enc.shape[0], 1, 4)), jnp.float32)
        return payload, err[:, 0]

    def decode(self, enc, series_len: int):
        payload, err = self.split(enc)
        raw = jnp.reshape(payload, (enc.shape[0], series_len, 2))
        rows = jax.lax.bitcast_convert_type(raw, jnp.bfloat16)
        return rows.astype(jnp.float32), err


def sax_segments_for(series_len: int) -> int:
    """Segment count for the sax-residual codec: the default when it divides
    ``series_len``, else the largest divisor of ``series_len`` <= default."""
    m = min(S.NUM_SAX_SEGMENTS, series_len)
    while series_len % m:
        m -= 1
    return m


@functools.lru_cache(maxsize=1)
def _sax_value_table() -> np.ndarray:
    """Per-code reconstruction values: midpoints of the iSAX breakpoint
    cells, with the open outer cells clamped half a unit past the edge.

    Computed in host numpy (scipy ``ndtri``) so it is a plain constant —
    safe to close over inside jit traces, unlike ``S.sax_breakpoints``.
    The table only has to agree between encode and decode; soundness comes
    from the embedded err bound, not from matching jax's ndtri bit-for-bit.
    """
    from scipy.special import ndtri

    qs = np.arange(1, S.SAX_ALPHABET, dtype=np.float64) / S.SAX_ALPHABET
    bp = ndtri(qs)
    lo = np.concatenate(([bp[0] - 1.0], bp))
    hi = np.concatenate((bp, [bp[-1] + 1.0]))
    return ((lo + hi) / 2.0).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class SaxResidualCodec:
    """iSAX reconstruction + int8 residual + embedded scale and error bound.

    Row layout (``W = m + n + 8`` bytes, ~26% of raw for n >= 32)::

        [ m bytes : uint8 iSAX codes ][ n bytes : int8 residual ]
        [ 4 bytes : float32 residual scale ][ 4 bytes : float32 err bound ]

    ``decode`` rebuilds the PAA step function from the codes via a fixed
    256-entry value table (breakpoint-cell midpoints), then adds the
    dequantized residual.  The residual is quantized per row with
    ``scale = max|residual| / 127``, so the bound stays tight on smooth
    rows and the stored ``err`` keeps pruning sound on rough ones.
    """

    name: str = "sax-residual"
    exact: bool = False

    def row_bytes(self, series_len: int) -> int:
        return sax_segments_for(series_len) + series_len + 8

    def encode(self, block: np.ndarray) -> np.ndarray:
        rows = np.ascontiguousarray(block, dtype=np.float32)
        num, n = rows.shape
        m = sax_segments_for(n)
        table = _sax_value_table()
        codes = np.asarray(S.isax(jnp.asarray(rows), m))
        recon = np.repeat(table[codes], n // m, axis=1)
        resid = rows - recon
        scale = (np.max(np.abs(resid), axis=1) / 127.0).astype(np.float32)
        scale = np.maximum(scale, np.float32(1e-30))  # avoid 0-div on decode
        q = np.clip(np.rint(resid / scale[:, None]), -127, 127).astype(np.int8)
        out = np.empty((num, self.row_bytes(n)), dtype=np.uint8)
        out[:, :m] = codes
        out[:, m:m + n] = q.view(np.uint8)
        out[:, m + n:m + n + 4] = scale.view(np.uint8).reshape(-1, 4)
        out[:, m + n + 4:] = np.zeros((num, 4), np.uint8)
        # Bound the error against the *actual* decode output (device
        # arithmetic may fuse differently than a host mirror would), then
        # patch the bound into the reserved tail bytes.
        decoded = np.asarray(self.decode(jnp.asarray(out), n)[0])
        err = _err_bound(rows, decoded)
        out[:, m + n + 4:] = err.view(np.uint8).reshape(-1, 4)
        return out

    def decode(self, enc, series_len: int):
        n = series_len
        m = sax_segments_for(n)
        codes = enc[:, :m].astype(jnp.int32)
        q = jax.lax.bitcast_convert_type(enc[:, m:m + n], jnp.int8)
        scale = jax.lax.bitcast_convert_type(
            jnp.reshape(enc[:, m + n:m + n + 4], (enc.shape[0], 1, 4)),
            jnp.float32)[:, 0]
        err = jax.lax.bitcast_convert_type(
            jnp.reshape(enc[:, m + n + 4:], (enc.shape[0], 1, 4)),
            jnp.float32)[:, 0]
        table = jnp.asarray(_sax_value_table())
        recon = jnp.repeat(table[codes], n // m, axis=1)
        rows = recon + q.astype(jnp.float32) * scale[:, None]
        return rows, err


_REGISTRY: dict[str, Codec] = {}


def register_codec(name: str) -> Callable[[Callable[[], Codec]], Callable[[], Codec]]:
    """Class/factory decorator: ``@register_codec("name")`` registers the
    codec produced by calling the decorated object with no arguments."""

    def deco(factory):
        codec = factory()
        if codec.name != name:
            raise ValueError(
                f"codec name mismatch: registered as {name!r} but "
                f"instance reports {codec.name!r}")
        _REGISTRY[name] = codec
        return factory

    return deco


def list_codecs() -> tuple[str, ...]:
    """Registered codec names, registration order (``raw`` first)."""
    return tuple(_REGISTRY)


def get_codec(name: str) -> Codec:
    """Resolve a codec by name; raises ``ValueError`` on unknown names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; expected one of {list_codecs()}"
        ) from None


register_codec("raw")(RawCodec)
register_codec("bf16")(Bf16Codec)
register_codec("sax-residual")(SaxResidualCodec)

#: Valid ``codec=`` values for CLIs and ``SearchConfig`` ("auto" = follow
#: whatever the opened index was encoded with).
CODEC_CHOICES = ("auto",) + tuple(_REGISTRY)
