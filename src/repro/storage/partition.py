"""Shard plans for serving one on-disk index from a mesh of readers.

A *shard plan* cuts the committed base generation into ``num_shards``
contiguous **leaf runs** (leaf in-order == file order, so a leaf range is a
row range) balanced by row count. The plan is what makes distributed
out-of-core serving (``repro.distributed.ooc``) safe and cheap:

* contiguity at leaf boundaries means every shard streams its rows through
  the same sequential-run machinery as the single-host backends — no leaf
  is ever split across two readers;
* balancing by *rows* (not leaves) bounds the worst shard's disk traffic,
  which is what the per-query latency of the merged answer waits on;
* determinism (pure function of the leaf tables) means a plan recorded in
  the manifest at commit time and a plan derived on open from an old
  manifest are the same plan — old indexes shard without a rewrite.

``write_manifest`` records one :func:`partition_section` per base
generation (shard counts :data:`RECORDED_SHARD_COUNTS`); :func:`shard_plan`
prefers the recorded plan and derives it from ``layout.npz`` leaf tables
when the manifest predates this section (format v1–v3 without it).

Guardrail: a plan whose ``max/min`` shard row ratio exceeds
:data:`BALANCE_WARN_RATIO` warns at construction (and the serving backend
flags it in ``Telemetry.dist``) — a skewed tree can starve all but one
reader, and the caller should know before benchmarking a mesh against it.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np

#: max/min shard row ratio above which a plan is flagged as imbalanced.
BALANCE_WARN_RATIO = 2.0

#: Shard counts whose plans are precomputed into the manifest at commit
#: time. Any other count is derived on demand (same deterministic cut).
RECORDED_SHARD_COUNTS = (2, 4, 8)

PARTITION_SECTION_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """``num_shards`` contiguous leaf/row ranges over one base generation.

    ``leaf_bounds``/``row_bounds`` are ascending fence posts of length
    ``num_shards + 1``: shard ``i`` owns leaves
    ``[leaf_bounds[i], leaf_bounds[i+1])`` and file rows
    ``[row_bounds[i], row_bounds[i+1])``.
    """
    num_shards: int
    leaf_bounds: tuple[int, ...]
    row_bounds: tuple[int, ...]

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError(f"num_shards={self.num_shards}; expected >= 1")
        for name in ("leaf_bounds", "row_bounds"):
            b = getattr(self, name)
            if len(b) != self.num_shards + 1:
                raise ValueError(f"{name} has {len(b)} fence posts; expected "
                                 f"{self.num_shards + 1}")
            if any(b[i] > b[i + 1] for i in range(len(b) - 1)):
                raise ValueError(f"{name} must be ascending: {b}")

    def leaf_range(self, shard: int) -> tuple[int, int]:
        return self.leaf_bounds[shard], self.leaf_bounds[shard + 1]

    def row_range(self, shard: int) -> tuple[int, int]:
        return self.row_bounds[shard], self.row_bounds[shard + 1]

    @property
    def shard_rows(self) -> tuple[int, ...]:
        return tuple(self.row_bounds[i + 1] - self.row_bounds[i]
                     for i in range(self.num_shards))

    @property
    def total_rows(self) -> int:
        return self.row_bounds[-1] - self.row_bounds[0]

    @property
    def imbalance(self) -> float:
        """max/min shard row count; ``inf`` when a shard is empty while
        another is not, ``1.0`` for a trivially empty plan."""
        rows = self.shard_rows
        if max(rows, default=0) == 0:
            return 1.0
        if min(rows) == 0:
            return float("inf")
        return max(rows) / min(rows)

    @property
    def balanced(self) -> bool:
        return self.imbalance <= BALANCE_WARN_RATIO

    def to_manifest(self) -> dict:
        return {"leaf_bounds": list(self.leaf_bounds),
                "row_bounds": list(self.row_bounds)}

    @classmethod
    def from_manifest(cls, num_shards: int, entry: dict) -> "ShardPlan":
        return cls(num_shards=int(num_shards),
                   leaf_bounds=tuple(int(b) for b in entry["leaf_bounds"]),
                   row_bounds=tuple(int(b) for b in entry["row_bounds"]))


def _warn_imbalance(plan: ShardPlan, origin: str) -> None:
    if not plan.balanced:
        warnings.warn(
            f"shard plan ({origin}) is imbalanced: per-shard rows "
            f"{plan.shard_rows} (max/min ratio "
            f"{plan.imbalance:.2f} > {BALANCE_WARN_RATIO}); a skewed tree "
            f"starves all but the largest shard's reader — consider fewer "
            f"shards or rebuilding with a smaller leaf_capacity",
            RuntimeWarning, stacklevel=3)


def partition_plan(leaf_start, leaf_count, num_shards: int, *,
                   warn: bool = True) -> ShardPlan:
    """Cut the leaf tables into ``num_shards`` contiguous runs balanced by
    row count: fence post ``i`` is the first leaf whose cumulative rows
    reach ``i/num_shards`` of the total (quantile cuts snapped to leaf
    boundaries). Pure and deterministic — the recorded and the derived
    plan for the same generation are identical.

    Every shard gets at least one leaf when there are enough leaves;
    otherwise trailing shards are empty (and the plan warns, since an
    empty shard next to a populated one is infinitely imbalanced).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards={num_shards}; expected >= 1")
    starts = np.asarray(leaf_start, np.int64)
    counts = np.asarray(leaf_count, np.int64)
    if starts.shape != counts.shape or starts.ndim != 1:
        raise ValueError(
            f"leaf_start/leaf_count must be matching 1-D tables; got "
            f"{starts.shape} vs {counts.shape}")
    num_leaves = int(starts.shape[0])
    cum = np.cumsum(counts)
    total = int(cum[-1]) if num_leaves else 0
    row_end = int(starts[-1] + counts[-1]) if num_leaves else 0

    leaf_bounds = [0]
    for i in range(1, num_shards):
        target = total * i / num_shards
        m = int(np.searchsorted(cum, target, side="left")) + 1 \
            if num_leaves else 0
        if num_leaves >= num_shards:
            # leave room so every remaining shard still gets >= 1 leaf
            m = min(max(m, leaf_bounds[-1] + 1), num_leaves - (num_shards - i))
        else:
            m = min(max(m, leaf_bounds[-1]), num_leaves)
        leaf_bounds.append(m)
    leaf_bounds.append(num_leaves)

    row_bounds = [int(starts[m]) if m < num_leaves else row_end
                  for m in leaf_bounds]
    row_bounds[0] = 0
    plan = ShardPlan(num_shards=num_shards,
                     leaf_bounds=tuple(leaf_bounds),
                     row_bounds=tuple(row_bounds))
    if warn:
        _warn_imbalance(plan, origin="derived")
    return plan


def partition_section(leaf_start, leaf_count,
                      counts: tuple[int, ...] = RECORDED_SHARD_COUNTS) -> dict:
    """The manifest ``partition`` section for one base generation: one
    precomputed plan per shard count in ``counts`` (plans for other counts
    derive on open from the same leaf tables)."""
    plans = {}
    for n in counts:
        plans[str(int(n))] = partition_plan(
            leaf_start, leaf_count, int(n), warn=False).to_manifest()
    return {"version": PARTITION_SECTION_VERSION,
            "balanced_by": "rows",
            "plans": plans}


def shard_plan(saved, num_shards: int, *, warn: bool = True) -> ShardPlan:
    """The shard plan an opened index serves under: the manifest-recorded
    plan for this generation when present (format >= this PR), else derived
    from the resident leaf tables (old indexes shard without a rewrite —
    the cut is the same either way)."""
    section = (saved.manifest or {}).get("partition") or {}
    entry = section.get("plans", {}).get(str(int(num_shards)))
    if entry is not None:
        plan = ShardPlan.from_manifest(num_shards, entry)
        if warn:
            _warn_imbalance(plan, origin="recorded")
        return plan
    return partition_plan(saved.small["leaf_start"],
                          saved.small["leaf_count"], num_shards, warn=warn)
