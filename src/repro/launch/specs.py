"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation).

``input_specs(cfg, shape)`` returns the exact batch pytree each step consumes:
train/prefill take token batches (+ stub frontend embeddings for vlm/audio);
decode takes (B, 1) tokens plus the KV-cache/state spec sized to the cell's
context length. ``state_specs`` mirrors init_train_state without allocating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelDef, get_model
from repro.models.arch import ArchConfig, ShapeConfig
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Batch spec for one (arch x shape) cell."""
    b = shape.global_batch
    s = shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    else:
        if cfg.family == "vlm":
            text = s - cfg.num_patches
            batch = {
                "tokens": _sds((b, text), jnp.int32),
                "patch_embeds": _sds((b, cfg.num_patches, cfg.d_patch),
                                     jnp.float32),
            }
        elif cfg.family == "audio":
            batch = {
                "tokens": _sds((b, s), jnp.int32),
                "frames": _sds((b, cfg.num_frames, cfg.d_model), jnp.float32),
            }
        else:
            batch = {"tokens": _sds((b, s), jnp.int32)}
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Decode-cache spec sized to the cell's context (eval_shape: no alloc)."""
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))


def param_specs(cfg: ArchConfig) -> dict:
    model = get_model(cfg)
    specs = jax.eval_shape(lambda k: model.init(k, cfg),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
    pd = jnp.dtype(cfg.param_dtype)

    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, pd)
        return x

    return jax.tree.map(cast, specs)


def opt_specs(params_spec, opt_cfg: AdamWConfig):
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_spec)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))
