"""kNN index-serving driver over the unified QueryEngine surface.

Builds a backend by name, wraps it in a :class:`QueryEngine` +
:class:`KnnServeEngine`, serves a stream of submitted queries through the
slot pool, and reports throughput, plan-cache behaviour and access-path
telemetry. ``--smoke`` runs a CI-sized workload and verifies every answer
against brute force.

    PYTHONPATH=src python -m repro.launch.serve_knn --smoke
    PYTHONPATH=src python -m repro.launch.serve_knn --backend scan \
        --num-series 100000 --requests 256 --slots 64
    PYTHONPATH=src python -m repro.launch.serve_knn --smoke --wave \
        --mixed-k --max-queue 16 --pack difficulty
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import (BuildConfig, IndexConfig, KnnServeConfig,
                       backend_names,
                       KnnServeEngine, QueryEngine, QueueFull, SearchConfig,
                       brute_force_knn, make_backend)
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=backend_names("memory"), default="local")
    ap.add_argument("--num-series", type=int, default=100_000)
    ap.add_argument("--length", type=int, default=128)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--difficulty", choices=DIFFICULTY_LEVELS, default="5%")
    ap.add_argument("--leaf-size", type=int, default=256)
    ap.add_argument("--l-max", type=int, default=8)
    ap.add_argument("--wave", action="store_true",
                    help="serve each wave through the fused wave plan")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission bound; submits past it are rejected "
                         "and retried after serving a wave")
    ap.add_argument("--pack", choices=("fifo", "difficulty"), default="fifo",
                    help="wave packing policy")
    ap.add_argument("--mixed-k", action="store_true",
                    help="alternate k and 2k requests to exercise sub-wave "
                         "grouping")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + brute-force verification (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.num_series = min(args.num_series, 4096)
        args.length = min(args.length, 64)
        args.requests = min(args.requests, 24)
        args.slots = min(args.slots, 8)

    print(f"generating {args.num_series} series of length {args.length} ...")
    data = random_walks(jax.random.PRNGKey(0), args.num_series, args.length)

    cfg = IndexConfig(
        build=BuildConfig(leaf_capacity=args.leaf_size),
        search=SearchConfig(k=args.k, l_max=args.l_max,
                            chunk=min(1024, args.num_series),
                            scan_block=min(4096, args.num_series)))
    t0 = time.time()
    backend = make_backend(args.backend, data, index_config=cfg)
    print(f"backend '{args.backend}' ready in {time.time() - t0:.1f}s: "
          f"{backend.describe()}")

    serve = KnnServeEngine(QueryEngine(backend),
                           KnnServeConfig(batch_slots=args.slots, k=args.k,
                                          wave=args.wave,
                                          max_queue=args.max_queue,
                                          pack=args.pack))

    workload = np.asarray(make_query_workload(
        jax.random.PRNGKey(1), data, args.requests, args.difficulty))
    ks = [args.k if (i % 2 == 0 or not args.mixed_k) else 2 * args.k
          for i in range(len(workload))]

    t0 = time.time()
    rids = []
    for q, k in zip(workload, ks):
        while True:
            try:
                rids.append(serve.submit(q, k=k))
                break
            except QueueFull:   # backpressure: free slots, then retry
                serve.step()
    answers = serve.drain()
    dt = time.time() - t0
    assert set(answers) == set(rids) and serve.pending() == 0
    if not answers:
        print("no requests submitted — nothing to serve")
        return

    tele = serve.telemetry()
    pc, sv = tele["plan_cache"], tele["serving"]
    print(f"\nserved {len(answers)} queries in {dt:.2f}s "
          f"({len(answers) / dt:.1f} q/s, "
          f"{1e3 * dt / len(answers):.2f} ms/query incl. compile)")
    print(f"plan cache: {pc['hits']} hits / {pc['misses']} misses "
          f"({pc['compiles']} compiles, {pc['compile_s']:.2f}s compiling)")
    print(f"paths: {tele['paths']}  pruning: "
          f"eapca={tele['pruning']['eapca_mean']:.3f} "
          f"sax={tele['pruning']['sax_mean']:.3f}")
    print(f"serving: waves={sv['waves']} wave_mode={sv['wave_mode']} "
          f"pack={sv['pack']} rejected={sv['rejected']} "
          f"failed={sv['failed']} scored={sv['difficulty_scored']}")
    if "ooc" in tele:
        ooc = tele["ooc"]
        print(f"ooc: rows_streamed={ooc['rows_streamed']} "
              f"runs_deduped={ooc['runs_deduped']} "
              f"wave_rows_shared={ooc['wave_rows_shared']}")

    if args.smoke:
        if sv["failed"]:
            raise SystemExit(f"smoke: {sv['failed']} requests failed")
        for k in sorted(set(ks)):
            rows = [i for i, kk in enumerate(ks) if kk == k]
            bf_d, _ = brute_force_knn(
                data, jax.numpy.asarray(workload[rows]), k)
            got = np.stack([answers[rids[i]].dists for i in rows])
            if not np.allclose(got, np.asarray(bf_d), rtol=1e-3, atol=1e-3):
                raise SystemExit(f"smoke exactness violation at k={k}")
        print(f"smoke exactness vs brute force — OK "
              f"(k groups: {sorted(set(ks))})")


if __name__ == "__main__":
    main()
