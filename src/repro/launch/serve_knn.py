"""kNN index-serving driver over the unified QueryEngine surface.

Builds a backend by name, wraps it in a :class:`QueryEngine` +
:class:`KnnServeEngine`, serves a stream of submitted queries through the
slot pool, and reports throughput, plan-cache behaviour and access-path
telemetry. ``--smoke`` runs a CI-sized workload and verifies every answer
against brute force.

    PYTHONPATH=src python -m repro.launch.serve_knn --smoke
    PYTHONPATH=src python -m repro.launch.serve_knn --backend scan \
        --num-series 100000 --requests 256 --slots 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import (BACKEND_NAMES, BuildConfig, IndexConfig, KnnServeConfig,
                       KnnServeEngine, QueryEngine, SearchConfig,
                       brute_force_knn, make_backend)
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKEND_NAMES, default="local")
    ap.add_argument("--num-series", type=int, default=100_000)
    ap.add_argument("--length", type=int, default=128)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--difficulty", choices=DIFFICULTY_LEVELS, default="5%")
    ap.add_argument("--leaf-size", type=int, default=256)
    ap.add_argument("--l-max", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + brute-force verification (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        args.num_series = min(args.num_series, 4096)
        args.length = min(args.length, 64)
        args.requests = min(args.requests, 24)
        args.slots = min(args.slots, 8)

    print(f"generating {args.num_series} series of length {args.length} ...")
    data = random_walks(jax.random.PRNGKey(0), args.num_series, args.length)

    cfg = IndexConfig(
        build=BuildConfig(leaf_capacity=args.leaf_size),
        search=SearchConfig(k=args.k, l_max=args.l_max,
                            chunk=min(1024, args.num_series),
                            scan_block=min(4096, args.num_series)))
    t0 = time.time()
    backend = make_backend(args.backend, data, index_config=cfg)
    print(f"backend '{args.backend}' ready in {time.time() - t0:.1f}s: "
          f"{backend.describe()}")

    serve = KnnServeEngine(QueryEngine(backend),
                           KnnServeConfig(batch_slots=args.slots, k=args.k))

    workload = np.asarray(make_query_workload(
        jax.random.PRNGKey(1), data, args.requests, args.difficulty))
    rids = [serve.submit(q) for q in workload]
    print(f"submitted {len(rids)} requests "
          f"({serve.pending()} pending, slots={args.slots})")

    t0 = time.time()
    answers = serve.drain()
    dt = time.time() - t0
    assert set(answers) == set(rids) and serve.pending() == 0
    if not answers:
        print("no requests submitted — nothing to serve")
        return

    tele = serve.telemetry()
    pc = tele["plan_cache"]
    print(f"\nserved {len(answers)} queries in {dt:.2f}s "
          f"({len(answers) / dt:.1f} q/s, "
          f"{1e3 * dt / len(answers):.2f} ms/query incl. compile)")
    print(f"plan cache: {pc['hits']} hits / {pc['misses']} misses "
          f"({pc['compiles']} compiles, {pc['compile_s']:.2f}s compiling)")
    print(f"paths: {tele['paths']}  pruning: "
          f"eapca={tele['pruning']['eapca_mean']:.3f} "
          f"sax={tele['pruning']['sax_mean']:.3f}")

    if args.smoke:
        bf_d, _ = brute_force_knn(data, jax.numpy.asarray(workload), args.k)
        got = np.stack([answers[r].dists for r in rids])
        if not np.allclose(got, np.asarray(bf_d), rtol=1e-3, atol=1e-3):
            raise SystemExit("smoke exactness violation")
        print("smoke exactness vs brute force — OK")


if __name__ == "__main__":
    main()
