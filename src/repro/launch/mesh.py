"""Production mesh definition (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — only the dry-run
process (which sets XLA_FLAGS first) materializes the 256/512-device mesh.

Axis roles:
  * ``pod``   — inter-pod data parallelism (2 pods = 512 chips)
  * ``data``  — intra-pod DP + FSDP (ZeRO-3 param sharding)
  * ``model`` — TP (heads/FFN), EP (experts), SP (long-context KV/sequence)
"""
from __future__ import annotations

import jax

from repro.distributed.compat import auto_axis_types as _auto
from repro.distributed.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_axis: int = 1):
    """Whatever devices exist on this host (tests, examples): a (data, model)
    mesh with the requested model-axis width."""
    n = len(jax.devices())
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    return _make_mesh((n // model_axis, model_axis), ("data", "model"),
                      axis_types=_auto(2))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch/FSDP axes present in this mesh ('pod' included when there)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
