"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

Fault tolerance (1000-node posture, DESIGN.md §5):
  * checkpoint every --ckpt-every steps, atomic rename, restart-exact
    (data pipeline state = (step, seed) is in the checkpoint metadata);
  * on startup the driver resumes from the latest checkpoint automatically;
  * straggler mitigation: training is fully synchronous SPMD — a slow chip
    delays its collective; the mitigations here are (a) deterministic
    skip-ahead batches (any worker can recompute batch t from (seed, t)
    alone, so respawned workers rejoin without coordination), (b) bounded
    startup via the checkpoint, (c) the elastic path: a checkpoint taken on
    N chips restores onto M chips (tests/test_distributed.py);
  * gradient compression: --compress enables int8 error-feedback DP
    all-reduce (shard_map over the data axis; see train/compression.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import get_model
from repro.train import (TrainConfig, load_checkpoint, make_train_step,
                         save_checkpoint)
from repro.train.checkpoint import latest_step
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state


def synth_batch(seed: int, step: int, cfg, batch: int, seq: int) -> dict:
    """Deterministic batch t = f(seed, t): the restart/straggler contract."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    out = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.num_patches, cfg.d_patch))
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(key, (batch, cfg.num_frames,
                                                cfg.d_model))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(learning_rate=args.lr, warmup_steps=10,
                              total_steps=args.steps),
        microbatches=args.microbatches)
    step_fn = jax.jit(make_train_step(model, cfg, tcfg))

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, meta = load_checkpoint(args.ckpt_dir)
        params, opt = state["params"], state["opt"]
        opt["step"] = opt["step"].astype(jnp.int32)
        start = meta["step"]
        print(f"resumed from step {start}")
    else:
        params, opt = init_train_state(model, cfg, tcfg,
                                       jax.random.PRNGKey(args.seed))

    t0 = time.time()
    for step in range(start, args.steps):
        batch = synth_batch(args.seed, step, cfg, args.batch, args.seq)
        params, opt, metrics = step_fn(params, opt, batch)
        if (step + 1) % args.log_every == 0:
            print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / (step - start + 1):.2f}s/step)",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt},
                            {"rng_seed": args.seed})
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
