"""Post-compile HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes; collective traffic is NOT in
there, so we parse the optimized HLO text and sum the result-buffer sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (the spec'd methodology). Hardware model: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re

# --- TPU v5e hardware constants (per chip) ----------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s/link
HBM_BYTES = 16 * 2**30            # 16 GiB

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum buffer sizes of every typed shape in a (possibly tuple) string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type byte totals (+ op counts) from optimized HLO.

    XLA CPU *promotes* bf16 reductions to f32 (``to_apply=%..._promoted`` with
    a convert-fused operand); real TPUs reduce bf16 natively, so promoted
    reduction bytes are counted at half width (the semantic payload).
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # op lines look like:  %x = bf16[..]{..} all-gather(...)  or
        #                      %x = (f32[..], f32[..]) all-reduce(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        shape_str, op = m.groups()
        base = op.rstrip("0123456789.-")      # all-gather-start etc.
        for kind in _COLLECTIVES:
            if base == kind or base == kind + "-start" or op.startswith(kind):
                nbytes = _shape_bytes(shape_str)
                if "promoted" in stripped and "f32" in shape_str:
                    nbytes //= 2              # CPU-promoted bf16 reduction
                out[kind]["bytes"] += nbytes
                out[kind]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int) -> Roofline:
    """The three-term roofline (EXPERIMENTS.md §Roofline).

    cost_analysis flops/bytes are whole-program (all partitions): divide by
    chips for the per-chip rate. Collective bytes are summed over the
    program's collective result buffers; each chip's link carries ~1/chips of
    the total ring traffic per the spec's formula.
    """
    return Roofline(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_accessed / (chips * HBM_BW),
        collective_s=coll_bytes / (chips * ICI_BW),
        flops=flops, bytes_accessed=bytes_accessed, coll_bytes=coll_bytes,
        chips=chips)
