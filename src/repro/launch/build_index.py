"""Index lifecycle CLI: build → append → compact → query, one store.

The cross-process persistence harness CI runs (jobs in .github/workflows):
process 1 builds an index out-of-core and saves it; process 2 appends a
journal segment; process 3 compacts; process 4 regenerates the same
deterministic collection, loads the index, and asserts the loaded backends
answer **bit-identically** to ones built in memory over the *whole*
(appended) collection — plus an out-of-core scan over a collection several
times larger than its memory budget.

    # build (chunked, streamed to disk) + one-shot equality check
    PYTHONPATH=src python -m repro.launch.build_index build \
        --out idx --num 8192 --length 64 --seed 7 --chunk-size 1024 \
        --verify-one-shot --json build.json

    # fresh process: append a journal segment (atomic manifest commit)
    PYTHONPATH=src python -m repro.launch.build_index append \
        --index idx --num 2048 --length 64 --seed 11 --json append.json

    # fresh process: fold the journal into a new base generation
    PYTHONPATH=src python -m repro.launch.build_index compact --index idx

    # fresh process: load + bit-identical parity vs in-memory backends
    PYTHONPATH=src python -m repro.launch.build_index query \
        --index idx --verify parity --json parity.json

    # out-of-core scan, collection >= 4x the budget
    PYTHONPATH=src python -m repro.launch.build_index query \
        --index idx --backend ooc-scan --memory-budget-mb 0.5 --verify exact
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import (BuildConfig, Hercules, backend_names,
                       HerculesIndex, IndexConfig, LocalBackend,
                       NpyChunkSource, QueryEngine, ScanBackend, SearchConfig,
                       ArrayChunkSource, brute_force_knn, build_index_to_disk,
                       list_codecs, make_disk_backend, open_index)
from repro.data import make_query_workload, random_walks


def _write_json(path: str | None, payload: dict) -> None:
    if not path:
        return
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


def _index_config(args) -> IndexConfig:
    return IndexConfig(
        build=BuildConfig(leaf_capacity=args.leaf_size),
        search=SearchConfig(k=args.k, l_max=args.l_max,
                            chunk=min(1024, args.num),
                            scan_block=min(4096, args.num),
                            prefetch=getattr(args, "prefetch", "sync")))


def _synthetic(num: int, length: int, seed: int) -> np.ndarray:
    return np.asarray(random_walks(jax.random.PRNGKey(seed), num, length))


def cmd_build(args) -> None:
    if args.input:
        source = NpyChunkSource(args.input, args.chunk_size)
        args.num, args.length = source.num_series, source.series_len
        provenance = {"kind": "npy", "path": args.input}
    else:
        data = _synthetic(args.num, args.length, args.seed)
        source = ArrayChunkSource(data, args.chunk_size)
        provenance = {"kind": "synthetic", "seed": args.seed,
                      "num": args.num, "length": args.length}

    cfg = _index_config(args)
    t0 = time.perf_counter()
    manifest = build_index_to_disk(source, args.out, cfg,
                                   extra_meta={"data": provenance},
                                   codec=args.codec)
    build_s = time.perf_counter() - t0
    thr = source.num_series / max(build_s, 1e-9)
    print(f"built + saved {source.num_series} x {source.series_len} in "
          f"{build_s:.2f}s ({thr:.0f} series/s, chunks of {args.chunk_size}, "
          f"codec {args.codec}) -> {args.out}")

    rows = {"num_series": source.num_series, "series_len": source.series_len,
            "chunk_size": args.chunk_size, "build_seconds": round(build_s, 3),
            "series_per_second": round(thr, 1), "codec": args.codec,
            "manifest_build": manifest["extra"]["build"]}

    if args.verify_one_shot:
        if args.input:
            raise SystemExit("--verify-one-shot needs a synthetic build "
                             "(regenerates the data in memory)")
        t0 = time.perf_counter()
        mem = HerculesIndex.build(data, cfg)
        rows["oneshot_build_seconds"] = round(time.perf_counter() - t0, 3)
        loaded = make_disk_backend("local", args.out).index
        for name in mem.tree._fields:
            a = np.asarray(getattr(mem.tree, name))
            b = np.asarray(getattr(loaded.tree, name))
            if not np.array_equal(a, b):
                raise SystemExit(f"chunked tree differs from one-shot: {name}")
        for name in ("lrd", "lsd", "perm", "leaf_start", "leaf_count",
                     "leaf_synopsis"):
            a = np.asarray(getattr(mem.layout, name))
            b = np.asarray(getattr(loaded.layout, name))
            if not np.array_equal(a, b):
                raise SystemExit(f"chunked layout differs from one-shot: {name}")
        print("chunked streamed build == one-shot in-memory build "
              "(tree + layout bit-identical)")
        rows["oneshot_equal"] = True
    _write_json(args.json, rows)


def _regenerate(saved) -> np.ndarray:
    prov = saved.manifest["extra"].get("data", {})
    parts = prov["parts"] if prov.get("kind") == "concat" else [prov]
    if all(p.get("kind") == "synthetic" for p in parts):
        return np.concatenate(
            [_synthetic(p["num"], p["length"], p["seed"]) for p in parts])
    # fall back to the collection recorded in the LRD file itself
    return saved.original_data()


def cmd_append(args) -> None:
    if args.input:
        data = np.load(args.input).astype(np.float32)
        provenance = {"kind": "npy", "path": args.input}
    else:
        data = _synthetic(args.num, args.length, args.seed)
        provenance = {"kind": "synthetic", "seed": args.seed,
                      "num": args.num, "length": args.length}
    with Hercules.open(args.index, "a") as hx:
        t0 = time.perf_counter()
        seg = hx.append(data, chunk_size=args.chunk_size,
                        provenance=provenance)
        dt = time.perf_counter() - t0
        thr = seg["rows"] / max(dt, 1e-9)
        print(f"appended segment {seg['name']} ({seg['rows']} x "
              f"{seg['series_len']}) in {dt:.2f}s ({thr:.0f} series/s); "
              f"{hx.pending_rows} rows pending compaction")
        _write_json(args.json, {
            "index": args.index, "segment": seg["name"], "rows": seg["rows"],
            "append_seconds": round(dt, 3),
            "series_per_second": round(thr, 1),
            "pending_rows": hx.pending_rows,
            "base_rows": hx.base_rows})


def cmd_compact(args) -> None:
    with Hercules.open(args.index, "a") as hx:
        pending, segs = hx.pending_rows, len(hx.journal["segments"])
        t0 = time.perf_counter()
        manifest = hx.compact(chunk_size=args.chunk_size, codec=args.codec)
        dt = time.perf_counter() - t0
        thr = hx.num_series / max(dt, 1e-9)
        print(f"compacted {pending} journal rows ({segs} segments) into "
              f"generation {hx.generation} in {dt:.2f}s "
              f"({thr:.0f} series/s replayed); base now {hx.base_rows} rows, "
              f"codec {hx.codec}")
        _write_json(args.json, {
            "index": args.index, "journal_rows": pending,
            "segments": segs, "generation": hx.generation,
            "codec": hx.codec,
            "compact_seconds": round(dt, 3),
            "series_per_second": round(thr, 1),
            "base_rows": hx.base_rows,
            "manifest_compact": manifest["extra"].get("compact", {})})


def _assert_readers_joined() -> None:
    """No chunk-reader thread may outlive its stream — ``close()`` joins
    them; a survivor here is a leak (checked by the CI persistence job)."""
    import threading

    from repro.data.pipeline import AsyncChunkReader

    leaked = [t.name for t in threading.enumerate()
              if t.name == AsyncChunkReader.THREAD_NAME and t.is_alive()]
    if leaked:
        raise SystemExit(f"leaked chunk-reader threads after close(): "
                         f"{leaked}")
    print("reader threads joined after close() — none leaked")


def _assert_same(name: str, a, b) -> None:
    for field, x, y in (("dists", a.dists, b.dists), ("ids", a.ids, b.ids)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            raise SystemExit(f"{name}: {field} differ between disk-fed and "
                             f"in-memory backends")
    print(f"{name}: bit-identical")


def cmd_query(args) -> None:
    from repro.storage.format import journal_of

    saved = open_index(args.index)
    pending = journal_of(saved.manifest)["rows"]
    if pending:
        # the disk backends serve the committed base; _regenerate (and the
        # in-memory reference backends) would cover base + journal
        if args.verify != "none":
            raise SystemExit(
                f"{args.index}: {pending} journal rows pending compaction — "
                f"verification compares the committed base only; run "
                f"`build_index compact --index {args.index}` first")
        print(f"# note: {pending} journal rows pending compaction are not "
              f"served by backend {args.backend!r}")
    k = args.k
    data = _regenerate(saved)
    queries = np.asarray(make_query_workload(
        jax.random.PRNGKey(args.query_seed), data, args.queries,
        args.difficulty))

    rows: dict = {"index": args.index, "backend": args.backend, "k": k,
                  "num_series": saved.num_series, "codec": saved.codec,
                  "memory_budget_mb": args.memory_budget_mb,
                  "prefetch": args.prefetch or saved.config.search.prefetch}

    streams = "ooc" in args.backend   # ooc-scan | ooc-local | dist-ooc
    if streams:
        # one budget→rows code path: the backends' own classmethod (the CLI
        # used to re-derive this by hand and could drift from _validate)
        from repro.core.engine import _OutOfCoreBase
        rows["stream_rows"] = _OutOfCoreBase.budget_stream_rows(
            args.memory_budget_mb, saved.series_len)

    t0 = time.perf_counter()
    backend = make_disk_backend(args.backend, args.index,
                                memory_budget_mb=args.memory_budget_mb,
                                prefetch=args.prefetch,
                                shards=args.shards)
    rows["load_seconds"] = round(time.perf_counter() - t0, 3)
    if args.backend == "ooc-scan":
        # a scan_block too large for the budget is auto-shrunk by the
        # backend itself (same behaviour from every entry point); report it
        base_block = saved.config.search.scan_block
        eff_block = backend.base_config.scan_block
        if eff_block != base_block:
            print(f"scan_block {base_block} -> {eff_block} "
                  f"(auto-fit to the {args.memory_budget_mb} MiB budget)")
        rows["scan_block"] = eff_block

    eng = QueryEngine(backend)
    t0 = time.perf_counter()
    res = eng.knn(queries, k=k)
    rows["query_seconds"] = round(time.perf_counter() - t0, 3)
    print(f"{args.backend}: loaded in {rows['load_seconds']}s, answered "
          f"{len(queries)} queries in {rows['query_seconds']}s")

    if streams:
        st = backend.stats()
        rows["read_wait_seconds"] = round(st["read_wait_seconds"], 4)
        rows["overlap_blocks"] = st["overlap_blocks"]
        rows["bytes_streamed"] = st["bytes_streamed"]
        rows["codec_fallbacks"] = st["codec_fallbacks"]
        if saved.codec != "raw":
            print(f"codec {saved.codec}: streamed {st['bytes_streamed']} "
                  f"bytes ({st['codec_refine_rows']} candidate rows "
                  f"re-checked at float32, {st['codec_fallbacks']} "
                  f"fallbacks)")
        if args.backend == "dist-ooc":
            ds = st["dist"]
            rows["dist"] = ds
            print(f"dist-ooc: {ds['shards']} shards streamed "
                  f"{ds['rows_streamed']} rows (imbalance "
                  f"{ds['imbalance']:.2f}, plan {ds['plan_imbalance']:.2f})")
            for rng_, touched in zip(ds["row_range"], ds["rows_touched"]):
                if touched is not None and not (
                        rng_[0] <= touched[0] and touched[1] <= rng_[1]):
                    raise SystemExit(
                        f"dist-ooc: shard reader touched rows {touched} "
                        f"outside its assigned range {rng_}")
            print("dist-ooc: every shard reader stayed inside its row range")
        if args.prefetch == "thread" and args.verify != "none":
            # thread-prefetch leg: answers must be bit-identical to the
            # synchronous reader on the same backend and budget
            sync_be = make_disk_backend(
                args.backend, args.index,
                memory_budget_mb=args.memory_budget_mb, prefetch="sync",
                shards=args.shards)
            _assert_same(f"{args.backend} prefetch thread==sync",
                         res, sync_be.knn(queries, k=k))
    _assert_readers_joined()

    if args.verify == "parity":
        # disk-fed vs in-memory, all three backends, bit-identical
        cfg = saved.config
        scfg = dict(k=k)
        mem_local = LocalBackend(HerculesIndex.build(data, cfg))
        _assert_same("local", make_disk_backend("local", args.index)
                     .knn(queries, **scfg), mem_local.knn(queries, **scfg))
        mem_scan = ScanBackend(data, cfg.search)
        disk_scan = make_disk_backend("scan", args.index)
        _assert_same("scan", disk_scan.knn(queries, **scfg),
                     mem_scan.knn(queries, **scfg))
        from repro.core.engine import ShardedBackend
        from repro.distributed.search import build_distributed_index
        shards = len(jax.devices())
        if saved.num_series % shards == 0:
            mem_sh = ShardedBackend(build_distributed_index(
                jax.numpy.asarray(data), shards, cfg))
            disk_sh = ShardedBackend(build_distributed_index(
                jax.numpy.asarray(saved.original_data()), shards, cfg))
            _assert_same("sharded", disk_sh.knn(queries, **scfg),
                         mem_sh.knn(queries, **scfg))
        rows["parity"] = "bit-identical"
    elif args.verify == "exact":
        bf_d, _ = brute_force_knn(jax.numpy.asarray(data),
                                  jax.numpy.asarray(queries), k)
        if not np.allclose(np.asarray(res.dists), np.asarray(bf_d),
                           rtol=1e-5, atol=1e-5):
            raise SystemExit(f"{args.backend}: answers not exact vs brute "
                             f"force")
        budget_bytes = args.memory_budget_mb * (1 << 20)
        coll_bytes = saved.num_series * saved.series_len * 4
        print(f"exact vs brute force — OK (collection {coll_bytes / 2**20:.2f}"
              f" MiB = {coll_bytes / budget_bytes:.1f}x the "
              f"{args.memory_budget_mb} MiB budget)")
        rows["exact"] = True
        rows["collection_over_budget"] = round(coll_bytes / budget_bytes, 2)
        rows["backend_stats"] = backend.stats()
    _write_json(args.json, rows)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="chunked build -> save to disk")
    b.add_argument("--out", required=True)
    b.add_argument("--input", default=None,
                   help=".npy collection (memory-mapped); else synthetic")
    b.add_argument("--num", type=int, default=8192)
    b.add_argument("--length", type=int, default=64)
    b.add_argument("--seed", type=int, default=7)
    b.add_argument("--chunk-size", type=int, default=4096)
    b.add_argument("--leaf-size", type=int, default=128)
    b.add_argument("--k", type=int, default=1)
    b.add_argument("--l-max", type=int, default=8)
    b.add_argument("--verify-one-shot", action="store_true",
                   help="assert chunked build == one-shot build bit-for-bit")
    b.add_argument("--prefetch", choices=("sync", "thread"), default="sync",
                   help="chunk-read scheduling for the build (thread = "
                        "async reader + two-slot host buffer; identical "
                        "bits either way)")
    b.add_argument("--codec", choices=list_codecs(), default="raw",
                   help="leaf codec for the base files (format v3); lossy "
                        "codecs stream fewer bytes, answers stay exact")
    b.add_argument("--json", default=None)
    b.set_defaults(fn=cmd_build)

    a = sub.add_parser("append",
                       help="append rows to a store as a journal segment")
    a.add_argument("--index", required=True)
    a.add_argument("--input", default=None,
                   help=".npy collection to append; else synthetic")
    a.add_argument("--num", type=int, default=2048)
    a.add_argument("--length", type=int, default=64)
    a.add_argument("--seed", type=int, default=11)
    a.add_argument("--chunk-size", type=int, default=4096)
    a.add_argument("--json", default=None)
    a.set_defaults(fn=cmd_append)

    c = sub.add_parser("compact",
                       help="fold journal segments into a new base "
                            "generation (bit-identical to a from-scratch "
                            "build over the whole collection)")
    c.add_argument("--index", required=True)
    c.add_argument("--chunk-size", type=int, default=4096)
    c.add_argument("--codec", choices=list_codecs(), default=None,
                   help="re-encode the new generation under this leaf codec "
                        "(default: keep the store's current codec)")
    c.add_argument("--json", default=None)
    c.set_defaults(fn=cmd_compact)

    q = sub.add_parser("query", help="load a saved index and answer queries")
    q.add_argument("--index", required=True)
    q.add_argument("--backend", choices=backend_names("disk"), default="local")
    q.add_argument("--memory-budget-mb", type=float, default=64.0)
    q.add_argument("--queries", type=int, default=16)
    q.add_argument("--difficulty", default="5%")
    q.add_argument("--query-seed", type=int, default=1)
    q.add_argument("--k", type=int, default=1)
    q.add_argument("--prefetch", choices=("sync", "thread"), default=None,
                   help="ooc read scheduling override (default: the saved "
                        "config's). thread additionally asserts bit-parity "
                        "against the sync reader when --verify is set")
    q.add_argument("--shards", type=int, default=None,
                   help="mesh size for --backend dist-ooc (default: one "
                        "shard per visible device; force host devices with "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    q.add_argument("--verify", choices=("none", "parity", "exact"),
                   default="none")
    q.add_argument("--json", default=None)
    q.set_defaults(fn=cmd_query)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
