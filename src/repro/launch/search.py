"""Hercules index-serving driver — the paper's own system end-to-end.

    PYTHONPATH=src python -m repro.launch.search --num-series 100000 \
        --length 128 --queries 100 --k 1 --difficulty 5%

Builds the index (construction stage), answers a query workload (query
answering stage), reports per-query latency, pruning ratios and access-path
distribution, and cross-checks exactness against the optimized scan.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import (BuildConfig, HerculesIndex, IndexConfig, SearchConfig,
                        pscan_knn)
from repro.data import DIFFICULTY_LEVELS, make_query_workload, random_walks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-series", type=int, default=100_000)
    ap.add_argument("--length", type=int, default=128)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--difficulty", choices=DIFFICULTY_LEVELS, default="5%")
    ap.add_argument("--leaf-size", type=int, default=1024)
    ap.add_argument("--l-max", type=int, default=80)
    ap.add_argument("--save", default="")
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args(argv)

    print(f"generating {args.num_series} series of length {args.length} ...")
    data = random_walks(jax.random.PRNGKey(0), args.num_series, args.length)

    cfg = IndexConfig(
        build=BuildConfig(leaf_capacity=args.leaf_size),
        search=SearchConfig(k=args.k, l_max=args.l_max))
    t0 = time.time()
    idx = HerculesIndex.build(data, cfg)
    t_build = time.time() - t0
    st = idx.stats()
    print(f"index built in {t_build:.1f}s: {st['num_leaves']} leaves, "
          f"depth {st['max_depth']}, max leaf {st['max_leaf']}")
    if args.save:
        idx.save(args.save)
        print(f"saved to {args.save}")

    queries = make_query_workload(jax.random.PRNGKey(1), data, args.queries,
                                  args.difficulty)
    res = idx.knn(queries, k=args.k)          # compile + warm
    jax.block_until_ready(res.dists)
    t0 = time.time()
    res = idx.knn(queries, k=args.k)
    jax.block_until_ready(res.dists)
    t_query = time.time() - t0

    paths = np.bincount(np.asarray(res.path), minlength=4)
    print(f"\n{args.queries} x {args.k}-NN [{args.difficulty}] in "
          f"{t_query:.2f}s ({1e3 * t_query / args.queries:.2f} ms/query)")
    print(f"  access paths: scan(eapca)={paths[0]} scan(sax)={paths[1]} "
          f"pruned={paths[2]}")
    print(f"  mean pruning: eapca={float(res.eapca_pr.mean()):.3f} "
          f"sax={float(res.sax_pr.mean()):.3f}")
    print(f"  mean data accessed: "
          f"{float(res.accessed.mean()) / args.num_series:.3%}")

    if args.verify:
        t0 = time.time()
        d_scan, _ = pscan_knn(data, queries, k=args.k)
        jax.block_until_ready(d_scan)
        t_scan = time.time() - t0
        ok = np.allclose(np.asarray(res.dists), np.asarray(d_scan),
                         rtol=1e-3, atol=1e-3)
        print(f"  PSCAN: {t_scan:.2f}s -> speedup "
              f"{t_scan / max(t_query, 1e-9):.2f}x; exact match: {ok}")
        if not ok:
            raise SystemExit("exactness violation")


if __name__ == "__main__":
    main()
