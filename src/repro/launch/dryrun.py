import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: the dry-run (and only the dry-run) builds the
# production mesh out of 512 placeholder host devices. Tests/benches see 1.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell and extract the roofline terms.

For each cell:
  * build ShapeDtypeStruct stand-ins (no allocation) for params, optimizer
    state, batch and cache;
  * jit the right step (train_step / prefill / decode) with in/out shardings
    from repro.distributed.sharding under the 16x16 (single-pod) or 2x16x16
    (multi-pod) mesh;
  * ``.lower().compile()`` — sharding mismatches, unsupported collectives or
    compile-time OOM are treated as bugs (non-zero exit);
  * record memory_analysis / cost_analysis / parsed collective bytes to
    ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.distributed.sharding import (batch_sharding, cache_sharding,
                                        install_activation_hook,
                                        param_sharding, shard_params_tree)
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (cache_specs, input_specs, opt_specs,
                                param_specs, tree_bytes)
from repro.models import SHAPES, LONG_CONTEXT_ARCHS, get_model
from repro.models.arch import ArchConfig, ShapeConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

# per-arch optimizer memory policy: 8-bit moments for the models where fp32
# moments cannot fit a single pod (DESIGN.md §5)
_INT8_MOMENT_ARCHS = ("llama3-405b", "granite-34b", "moonshot-v1-16b-a3b")


def arch_train_config(name: str) -> TrainConfig:
    moment = "int8" if name in _INT8_MOMENT_ARCHS else "float32"
    sched = "wsd" if name == "minicpm-2b" else "cosine"
    return TrainConfig(optimizer=AdamWConfig(moment_dtype=moment,
                                             schedule=sched))


def cell_is_skipped(arch: str, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return ("full-attention arch: 512k dense KV/attention is O(S^2) with "
                "no sub-quadratic path (DESIGN.md shape/skip matrix)")
    return None


def _opt_sharding_tree(opt_spec, params_spec, mesh):
    """Moments mirror their param's sharding. int8-packed moments use the
    blockwise-last-dim layout (optimizer.py): q (..., D/256, 256) inherits
    the param's leading-dim shardings and keeps the split dim's axis when the
    block count still divides — no resharding between gradient and moment
    update (§Perf iteration 3)."""
    from repro.distributed.sharding import _fits  # noqa

    param_sh = shard_params_tree(params_spec, mesh)

    def mirror(spec_sub, param_sh_sub):
        if isinstance(spec_sub, dict) and set(spec_sub) == {"q", "scale"}:
            q_shape = spec_sub["q"].shape
            pspec = tuple(param_sh_sub.spec)
            pspec = pspec + (None,) * (len(q_shape) - 1 - len(pspec))
            lead = pspec[: len(q_shape) - 2]
            last_ax = pspec[len(q_shape) - 2]
            ok = _fits(q_shape[-2], last_ax, mesh)
            q_spec = P(*lead, last_ax if ok else None, None)
            return {"q": NamedSharding(mesh, q_spec),
                    "scale": NamedSharding(mesh, q_spec)}
        if isinstance(spec_sub, dict):
            return {k: mirror(v, param_sh_sub[k]) for k, v in spec_sub.items()}
        if isinstance(spec_sub, (list, tuple)):
            return type(spec_sub)(mirror(v, param_sh_sub[i])
                                  for i, v in enumerate(spec_sub))
        return param_sh_sub

    return {
        "m": mirror(opt_spec["m"], param_sh),
        "v": mirror(opt_spec["v"], param_sh),
        "step": NamedSharding(mesh, P()),
    }


def _compile_cell(cfg, arch: str, shape: ShapeConfig, mesh):
    """Lower + compile one configuration. Returns (compiled, state_bytes)."""
    model = get_model(cfg)
    p_spec = param_specs(cfg)
    p_shard = shard_params_tree(p_spec, mesh)
    batch = input_specs(cfg, shape)
    b_shard = batch_sharding(batch, mesh)

    if shape.kind == "train":
        tcfg = arch_train_config(arch)
        o_spec = opt_specs(p_spec, tcfg.optimizer)
        o_shard = _opt_sharding_tree(o_spec, p_spec, mesh)
        step = make_train_step(model, cfg, tcfg)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_spec, o_spec, batch)
        state_bytes = tree_bytes(p_spec) + tree_bytes(o_spec)
    elif shape.kind == "prefill":
        c_spec = cache_specs(cfg, shape)
        c_shard = cache_sharding(c_spec, mesh)
        fn = lambda p, b, c: model.prefill(p, b, cfg, c)
        jitted = jax.jit(fn,
                         in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,))
        lowered = jitted.lower(p_spec, batch, c_spec)
        state_bytes = tree_bytes(p_spec) + tree_bytes(c_spec)
    else:  # decode
        c_spec = cache_specs(cfg, shape)
        c_shard = cache_sharding(c_spec, mesh)
        fn = lambda p, t, c: model.decode_step(p, t, cfg, c)
        jitted = jax.jit(fn,
                         in_shardings=(p_shard, b_shard["tokens"], c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,))
        lowered = jitted.lower(p_spec, batch["tokens"], c_spec)
        state_bytes = tree_bytes(p_spec) + tree_bytes(c_spec)
    return lowered.compile(), state_bytes


_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _analyze(compiled) -> dict:
    """Per-device cost vector: flops, bytes, per-collective bytes."""
    cost = compiled.cost_analysis() or {}
    coll = H.collective_bytes(compiled.as_text())
    vec = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for k in _COLL_KINDS:
        vec[f"coll:{k}"] = float(coll[k]["bytes"])
    vec["coll:total"] = float(coll["total_bytes"])
    return vec


def _recurrence_correction(cfg, shape: ShapeConfig) -> tuple[float, float]:
    """Analytic (flops, bytes) for time-recurrence scan bodies that XLA cost
    analysis counts once (documented approximation, EXPERIMENTS.md §Dry-run).

    rwkv6 WKV step: ~4 flops per (h, k, v) element; RG-LRU step: ~6 flops per
    rnn channel. Train multiplies by 4 (fwd + remat recompute + 2x bwd).
    Bytes: the fp32 state is read+written every step.
    """
    t = 1 if shape.kind == "decode" else shape.seq_len
    if t <= 1:
        return 0.0, 0.0
    b = shape.global_batch
    mult = 4.0 if shape.kind == "train" else 1.0
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_size
        per_step = h * cfg.rwkv_head_size ** 2
        flops = 4.0 * per_step * (t - 1) * b * cfg.num_layers * mult
        bytes_ = 8.0 * per_step * (t - 1) * b * cfg.num_layers * mult
        return flops, bytes_
    if cfg.family == "hybrid":
        n_rec = sum(1 for k in cfg._pattern() if k == "rec")
        rnn = cfg.d_rnn or cfg.d_model
        flops = 6.0 * rnn * (t - 1) * b * n_rec * mult
        bytes_ = 8.0 * rnn * (t - 1) * b * n_rec * mult
        return flops, bytes_
    return 0.0, 0.0


def _corrected_costs(arch: str, cfg, shape: ShapeConfig, mesh, raw: dict) -> dict:
    """Correct the scan-body single-count (tests/test_dryrun_units.py shows
    XLA CPU cost analysis does NOT multiply while bodies by trip count).

    Method: compile unrolled probes with 1 and 2 layers (same shapes and
    shardings), extrapolate linearly: cost(L) = probe1 + (L-1)*(probe2-probe1).
    Whisper extrapolates encoder and decoder depths independently. Archs that
    already unroll (recurrentgemma) keep raw values. Inner time recurrences
    (wkv / RG-LRU) get an analytic additive term.
    """
    probes_note = "none (unrolled model: raw HLO counts are exact)"
    if cfg.scan_layers:
        if cfg.family == "audio":
            c11, _ = _compile_cell(dataclasses.replace(
                cfg, encoder_layers=1, num_layers=1, scan_layers=False),
                arch, shape, mesh)
            c21, _ = _compile_cell(dataclasses.replace(
                cfg, encoder_layers=2, num_layers=1, scan_layers=False),
                arch, shape, mesh)
            c12, _ = _compile_cell(dataclasses.replace(
                cfg, encoder_layers=1, num_layers=2, scan_layers=False),
                arch, shape, mesh)
            v11, v21, v12 = _analyze(c11), _analyze(c21), _analyze(c12)
            corr = {k: max(0.0, v11[k]
                           + (cfg.encoder_layers - 1) * (v21[k] - v11[k])
                           + (cfg.num_layers - 1) * (v12[k] - v11[k]))
                    for k in v11}
            probes_note = "probe extrapolation over (enc_layers, dec_layers)"
        else:
            c1, _ = _compile_cell(dataclasses.replace(
                cfg, num_layers=1, scan_layers=False), arch, shape, mesh)
            c2, _ = _compile_cell(dataclasses.replace(
                cfg, num_layers=2, scan_layers=False), arch, shape, mesh)
            v1, v2 = _analyze(c1), _analyze(c2)
            corr = {k: max(0.0, v1[k] + (cfg.num_layers - 1) * (v2[k] - v1[k]))
                    for k in v1}
            probes_note = "probe extrapolation over num_layers (1, 2)"
    else:
        corr = dict(raw)

    rflops, rbytes = _recurrence_correction(cfg, shape)
    corr["flops"] += rflops / max(mesh.size, 1)     # per-device convention
    corr["bytes"] += rbytes / max(mesh.size, 1)
    corr["note"] = probes_note
    return corr


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower+compile one cell. Returns the result record (dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    install_activation_hook(mesh)
    t0 = time.time()
    compiled, state_bytes = _compile_cell(cfg, arch, shape, mesh)
    t_compile = time.time() - t0
    t_lower = 0.0

    # ---- analyses -----------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    raw = _analyze(compiled)
    t1 = time.time()
    corr = _corrected_costs(arch, cfg, shape, mesh, raw)
    t_probe = time.time() - t1
    flops = corr["flops"]
    bytes_accessed = corr["bytes"]
    coll_total = corr["coll:total"]
    coll = {k: {"bytes": corr[f"coll:{k}"]} for k in _COLL_KINDS}
    coll["total_bytes"] = coll_total
    coll["raw_uncorrected"] = {k: raw[f"coll:{k}"] for k in _COLL_KINDS}

    # cost_analysis is for the per-device SPMD module: whole-job totals are
    # per-device * chips (verified by calibration; see EXPERIMENTS.md)
    total_flops = flops * chips
    total_bytes = bytes_accessed * chips
    rf = H.roofline_terms(total_flops, total_bytes,
                          coll_total * chips, chips)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    n_flops_params = max(n_active - cfg.vocab_size * cfg.d_model, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_flops_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_flops_params * tokens
    else:
        tokens = shape.global_batch          # one new token per sequence
        model_flops = 2.0 * n_flops_params * tokens

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "status": "ok",
        "kind": shape.kind,
        "compile_s": round(t_compile, 2), "probe_s": round(t_probe, 2),
        "param_count": n_params, "active_param_count": n_active,
        "state_bytes_global": state_bytes,
        "state_bytes_per_chip": state_bytes / chips,
        "memory_analysis": mem_info,
        "cost_analysis": {"flops_per_device": flops,
                          "bytes_per_device": bytes_accessed,
                          "raw_flops_per_device": raw["flops"],
                          "raw_bytes_per_device": raw["bytes"],
                          "correction": corr["note"]},
        "collectives_per_device": coll,
        "roofline": {
            "compute_s": rf.compute_s, "memory_s": rf.memory_s,
            "collective_s": rf.collective_s, "dominant": rf.dominant,
            "bound_s": rf.bound_s,
        },
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / max(total_flops, 1.0),
        "tokens": tokens,
    }
    return rec


def lower_hercules(multi_pod: bool, tau: int = 100_000, l_max: int = 80,
                   tag: str = "", refine: str = "argsort"):
    """Dry-run the paper's own system: the distributed Hercules search step
    over a production-scale sharded collection (2M series x 256 per chip —
    0.5B series / ~2 TB single pod, 1B / ~4 TB multi-pod; the paper's Deep
    dataset is 0.27B x 96)."""
    import math

    from repro.core.layout import HerculesLayout
    from repro.core.search import SearchConfig
    from repro.core.tree import HerculesTree
    from repro.distributed.search import make_distributed_search

    mesh = make_production_mesh(multi_pod=multi_pod)
    d = mesh.size
    per = 1 << 21                      # series per chip
    n, m = 256, 16
    n_queries = 100                    # paper's workload size
    cfg = SearchConfig(k=1, l_max=l_max, chunk=4096, scan_block=8192,
                       refine_select=refine)
    blk = cfg.pad_multiple()
    n_pad = -(-(per + tau) // blk) * blk
    max_nodes = 8 * math.ceil(per / tau) + 64
    nleaves = 2 * math.ceil(per / tau)
    max_depth = 32
    axes = tuple(mesh.axis_names)

    def sds(shape, dtype, shard=True):
        spec = P(axes, *([None] * (len(shape) - 1))) if shard else P()
        return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype),
                                    sharding=NamedSharding(mesh, spec))

    tree = HerculesTree(
        parent=sds((d, max_nodes), jnp.int32),
        left=sds((d, max_nodes), jnp.int32),
        right=sds((d, max_nodes), jnp.int32),
        is_leaf=sds((d, max_nodes), bool),
        no_split=sds((d, max_nodes), bool),
        depth=sds((d, max_nodes), jnp.int32),
        endpoints=sds((d, max_nodes, m), jnp.int32),
        num_segs=sds((d, max_nodes), jnp.int32),
        split_lo=sds((d, max_nodes), jnp.int32),
        split_hi=sds((d, max_nodes), jnp.int32),
        split_use_std=sds((d, max_nodes), bool),
        split_value=sds((d, max_nodes), jnp.float32),
        synopsis=sds((d, max_nodes, m, 4), jnp.float32),
        count=sds((d, max_nodes), jnp.int32),
        num_nodes=sds((d,), jnp.int32),
    )
    layout = HerculesLayout(
        lrd=sds((d, n_pad, n), jnp.float32),
        lsd=sds((d, n_pad, m), jnp.uint8),
        perm=sds((d, n_pad), jnp.int32),
        inv_perm=sds((d, n_pad), jnp.int32),
        leaf_rank=sds((d, max_nodes), jnp.int32),
        leaf_node=sds((d, nleaves), jnp.int32),
        leaf_start=sds((d, nleaves), jnp.int32),
        leaf_count=sds((d, nleaves), jnp.int32),
        leaf_synopsis=sds((d, nleaves, m, 4), jnp.float32),
        leaf_endpoints=sds((d, nleaves, m), jnp.int32),
        leaf_seg_lens=sds((d, nleaves, m), jnp.float32),
        series_leaf_rank=sds((d, n_pad), jnp.int32),
        series_len=n, max_leaf=tau, num_leaves=nleaves, num_series=per,
    )
    offsets = sds((d, 1), jnp.int32)
    queries = sds((n_queries, n), jnp.float32, shard=False)

    t0 = time.time()

    def compile_with(search_cfg, nq=n_queries):
        q = queries if nq == n_queries else jax.ShapeDtypeStruct(
            (nq, n), jnp.float32,
            sharding=NamedSharding(mesh, P()))
        run = make_distributed_search(mesh, search_cfg, max_depth, tree, layout)
        return run.lower(tree, layout, offsets, q).compile()

    compiled = compile_with(cfg)
    t_compile = time.time() - t0

    # Probe correction: (a) the per-query lax.map body and (b) the phase-1
    # leaf-visit scan are counted once by XLA cost analysis. Probes compile
    # Q=1 programs with the visit loop UNROLLED at l_max 1 and 2, extrapolate
    # per-visit cost to l_max, then scale by the workload size. The chunked-
    # refinement while_loop stays counted at one chunk (trip count is query-
    # hardness-dependent by design): flops/bytes are a documented lower bound
    # there (EXPERIMENTS.md §Dry-run caveats).
    raw = _analyze(compiled)
    v1 = _analyze(compile_with(
        dataclasses.replace(cfg, l_max=1, unroll_visits=True), nq=1))
    v2 = _analyze(compile_with(
        dataclasses.replace(cfg, l_max=2, unroll_visits=True), nq=1))
    corr = {k: n_queries * max(0.0, v1[k] + (cfg.l_max - 1) * (v2[k] - v1[k]))
            for k in v1 if k != "note"}

    flops = corr["flops"]
    bytes_accessed = corr["bytes"]
    coll = {k: {"bytes": corr[f"coll:{k}"]} for k in _COLL_KINDS}
    coll["total_bytes"] = corr["coll:total"]
    coll["raw_uncorrected"] = {k: raw[f"coll:{k}"] for k in _COLL_KINDS}
    chips = mesh.size
    rf = H.roofline_terms(flops * chips, bytes_accessed * chips,
                          corr["coll:total"] * chips, chips)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        }
    except Exception as e:
        mem_info = {"error": str(e)}
    scan_flops = 3.0 * n_queries * (per * chips) * n
    state_bytes = tree_bytes(layout._asdict()) + sum(
        x.size * x.dtype.itemsize for x in tree)
    return {
        "arch": "hercules-search" + tag,
        "shape": f"{per * chips}x{n}_q{n_queries}_tau{tau}_L{l_max}",
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "status": "ok", "kind": "search",
        "compile_s": round(t_compile, 2),
        "state_bytes_global": state_bytes,
        "state_bytes_per_chip": state_bytes / chips,
        "memory_analysis": mem_info,
        "cost_analysis": {"flops_per_device": flops,
                          "bytes_per_device": bytes_accessed},
        "collectives_per_device": coll,
        "roofline": {"compute_s": rf.compute_s, "memory_s": rf.memory_s,
                     "collective_s": rf.collective_s,
                     "dominant": rf.dominant, "bound_s": rf.bound_s},
        "model_flops": scan_flops,
        "useful_flops_ratio": scan_flops / max(flops * chips, 1.0),
        "note": ("model_flops = PSCAN-equivalent exact-scan FLOPs; ratio > 1 "
                 "quantifies index pruning. while-loop refinement bodies are "
                 "counted once by XLA cost analysis (lower bound)."),
        "tokens": n_queries,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hercules", action="store_true",
                    help="dry-run the distributed Hercules search step")
    ap.add_argument("--herc-tau", type=int, default=100_000)
    ap.add_argument("--herc-lmax", type=int, default=80)
    ap.add_argument("--herc-tag", default="")
    ap.add_argument("--herc-refine", default="argsort",
                    choices=("argsort", "topk"))
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args(argv)

    if args.hercules:
        os.makedirs(args.out, exist_ok=True)
        fail = 0
        for mp in {"single": (False,), "multi": (True,),
                   "both": (False, True)}[args.mesh]:
            tag = (f"hercules-search{args.herc_tag}__"
                   f"{'multi' if mp else 'single'}")
            try:
                rec = lower_hercules(mp, tau=args.herc_tau,
                                     l_max=args.herc_lmax, tag=args.herc_tag,
                                     refine=args.herc_refine)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": "hercules-search", "shape": "search",
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": f"{type(e).__name__}: {e}"}
                fail += 1
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=2)
            extra = ""
            if rec["status"] == "ok":
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']} bound={r['bound_s']:.4g}s"
                         f" prune_ratio={rec['useful_flops_ratio']:.1f}x")
            print(f"[{rec['status']:7s}] {tag}{extra}", flush=True)
        sys.exit(1 if fail else 0)

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        out_path = os.path.join(args.out, tag + ".json")
        try:
            rec = lower_cell(arch, shape, mp)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dominant={r['dominant']} bound={r['bound_s']:.4g}s"
                     f" state/chip={rec['state_bytes_per_chip']/2**30:.2f}GiB"
                     f" compile={rec['compile_s']:.0f}s")
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
