"""LM serving driver (batched decode over any arch).

    PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b --smoke \
        --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import get_model
from repro.serve import ServeConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="codeqwen1.5-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(model, cfg, params,
                      ServeConfig(max_seq=args.prompt_len + args.new_tokens + 8,
                                  batch_slots=args.slots,
                                  max_new_tokens=args.new_tokens))
    rng = np.random.default_rng(0)
    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = rng.normal(
            size=(cfg.num_patches, cfg.d_patch)).astype(np.float32)
    if cfg.family == "audio":
        extras["frames"] = rng.normal(
            size=(cfg.num_frames, cfg.d_model)).astype(np.float32)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len)
        eng.submit(prompt, extras)
    t0 = time.time()
    out = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s)")
    print("sample:", out[0][:10])


if __name__ == "__main__":
    main()
