"""Next-token LM loss with masking + z-loss (fp32 throughout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig


def make_labels(batch: dict, cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """(labels, mask) aligned with the model's logits sequence.

    * plain LM: position i predicts tokens[i+1]; last position masked.
    * vlm: logits run over [patches | text]; only text-token targets count.
    * audio (whisper): teacher-forced decoder tokens, standard shift.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    if cfg.family == "vlm":
        p = cfg.num_patches
        comb = jnp.concatenate(
            [jnp.zeros((b, p), tokens.dtype), tokens], axis=1)
        labels = jnp.concatenate(
            [comb[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
        pos = jnp.arange(p + s)
        mask = ((pos >= p - 1) & (pos < p + s - 1)).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, (b, p + s))
        return labels, mask
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((b, s - 1), jnp.float32), jnp.zeros((b, 1), jnp.float32)],
        axis=1)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)
    return labels, mask


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array,
                  z_loss: float = 0.0) -> tuple[jax.Array, dict]:
    """Masked mean softmax CE. logits (B,S,V) fp32; labels/mask (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    metrics = {"ce": ce, "tokens": denom}
    loss = ce
    if z_loss:
        zl = jnp.sum(jnp.square(lse) * mask) / denom
        loss = loss + z_loss * zl
        metrics["z_loss"] = zl
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    metrics["accuracy"] = jnp.sum(acc * mask) / denom
    return loss, metrics
