"""Fault-tolerant checkpointing + elastic resharding.

Checkpoint/restart story (1000-node posture, DESIGN.md §5):
  * step-atomic writes: serialize to ``step_XXXXXXXX.npz.tmp`` then
    ``os.replace`` — a crash mid-write never corrupts the latest checkpoint;
  * restart is exact: the data pipeline state is (step, rng seed), both saved;
  * ``reshard_checkpoint`` re-maps a checkpoint onto a different device count
    (elastic scaling): checkpoints are stored *unsharded* (gathered), so
    resharding = re-slicing at load time under the new mesh — the host-side
    arrays are mesh-independent. For >HBM models the per-leaf npz layout
    supports streaming loads (leaf at a time).
"""
from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        items = tree.items()
    elif isinstance(tree, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(tree))
    else:
        return {prefix.rstrip(_SEP): tree}
    for k, v in items:
        out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def listify(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(re.fullmatch(r"\d+", k) for k in keys):
            return [listify(node[str(i)]) for i in range(len(keys))]
        return {k: listify(v) for k, v in node.items()}

    return listify(root)


def save_checkpoint(ckpt_dir: str, step: int, state: dict,
                    extra_meta: dict | None = None) -> str:
    """Atomically persist a pytree of arrays. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {"step": step, **(extra_meta or {})}
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **arrays)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int | None = None,
                    sharding_fn=None) -> tuple[dict, dict]:
    """Load (state, meta). ``sharding_fn(path, np_array) -> jax.Array`` lets
    callers place each leaf under the current mesh (elastic restore)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {}
        for key in z.files:
            if key == "__meta__":
                continue
            arr = z[key]
            flat[key] = (sharding_fn(key, arr) if sharding_fn
                         else jnp.asarray(arr))
    return _unflatten(flat), meta


def reshard_checkpoint(state: dict, mesh, sharding_rules) -> dict:
    """Re-place every leaf of a host-loaded state under ``mesh``.

    ``sharding_rules(path, leaf) -> jax.sharding.NamedSharding``. Because
    checkpoints store unsharded arrays, moving 16 -> 512 devices (or back) is
    just a placement decision here — the elastic-scaling primitive.
    """
    flat = _flatten(state)
    out = {}
    for path, leaf in flat.items():
        sh = sharding_rules(path, leaf)
        out[path] = jax.device_put(leaf, sh) if sh is not None else jnp.asarray(leaf)
    return _unflatten(out)
