"""Generic train/eval step builders over any ModelDef.

The returned step is a pure function suitable for jit/pjit: GSPMD handles the
data-parallel gradient reduction implicitly through sharded means. Gradient
compression (explicit int8 all-reduce) is the shard_map variant in
compression.py, used by launch/train.py when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import ModelDef
from repro.models.arch import ArchConfig
from repro.train.loss import cross_entropy, make_labels
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    z_loss: float = 1e-4
    moe_aux_weight: float = 1e-2
    microbatches: int = 1          # grad accumulation (sequential, jit-internal)


def make_train_step(model: ModelDef, cfg: ArchConfig,
                    tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, cfg)
        labels, mask = make_labels(batch, cfg)
        loss, metrics = cross_entropy(logits, labels, mask, tcfg.z_loss)
        if cfg.num_experts:
            loss = loss + tcfg.moe_aux_weight * aux
            metrics["moe_aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            # sequential grad accumulation: overlap-friendly (each microbatch's
            # psum can overlap the next microbatch's compute under GSPMD)
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                gsum, msum = carry
                (_, metrics), grads = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                msum = jax.tree.map(jnp.add, msum, metrics)
                return (gsum, msum), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            first = jax.tree.map(lambda x: x[0], mbatch)
            (_, m0), g0 = grad_fn(params, first)
            rest = jax.tree.map(lambda x: x[1:], mbatch)
            (grads, msum), _ = jax.lax.scan(acc_fn, (g0, m0), rest)
            inv = 1.0 / tcfg.microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            metrics = jax.tree.map(lambda m: m * inv, msum)
        else:
            (_, metrics), grads = grad_fn(params, batch)

        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: ModelDef, cfg: ArchConfig,
                   tcfg: TrainConfig | None = None) -> Callable:
    tcfg = tcfg or TrainConfig()

    def eval_step(params, batch):
        logits, _ = model.forward(params, batch, cfg)
        labels, mask = make_labels(batch, cfg)
        _, metrics = cross_entropy(logits, labels, mask)
        return metrics

    return eval_step


def init_train_state(model: ModelDef, cfg: ArchConfig, tcfg: TrainConfig,
                     key) -> tuple[dict, dict]:
    params = model.init(key, cfg)
    opt_state = adamw_init(params, tcfg.optimizer)
    return params, opt_state
