from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.train.loss import cross_entropy, make_labels  # noqa: F401
from repro.train.train_step import TrainConfig, make_train_step, make_eval_step  # noqa: F401
from repro.train.checkpoint import (  # noqa: F401
    save_checkpoint, load_checkpoint, latest_step, reshard_checkpoint,
)
from repro.train.compression import (  # noqa: F401
    compress_int8, decompress_int8, make_compressed_psum,
)
