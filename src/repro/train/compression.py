"""int8 error-feedback gradient compression for the DP all-reduce.

The distributed-optimization trick (DESIGN.md §5): inside a ``shard_map``
over the data axis, each worker quantizes its local gradient to int8 with a
per-tensor fp32 absmax scale, all-reduces the int8 payload (4x less ICI
traffic than fp32, 2x less than bf16), dequantizes, and keeps the
quantization residual in an **error-feedback buffer** added back before the
next step's compression — the contraction property that keeps SGD/Adam
convergent under biased compression (Karimireddy et al., 2019).

``make_compressed_psum`` returns a drop-in for ``jax.lax.psum`` over grads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8. Returns (q int8, scale fp32 scalar)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0
    q = jnp.round(x32 / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_compressed_psum(axis_name: str):
    """Returns fn(grads, error_buf) -> (mean grads, new error_buf).

    Must be called inside shard_map/pmap over ``axis_name``. The int8 payload
    is all-reduced (psum of int32-upcast to avoid overflow at <=2^23 workers);
    scales are all-maxed so every worker dequantizes identically.
    """

    def compressed_psum(grads, error_buf):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e            # error feedback
            q, scale = compress_int8(g32)
            # shared scale: max over workers keeps dequant consistent
            scale = jax.lax.pmax(scale, axis_name)
            q = jnp.round(g32 / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
            local_approx = q.astype(jnp.float32) * scale
            new_e = g32 - local_approx                  # residual for next step
            summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            mean = summed.astype(jnp.float32) * scale / n
            return mean, new_e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(error_buf)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return compressed_psum


def init_error_buffer(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
