"""AdamW with optional 8-bit (blockwise-quantized) moments.

The 8-bit moments are the distributed-optimization trick that makes the
llama3-405b train cell fit HBM (EXPERIMENTS.md §Dry-run): m and v are stored
as int8 with a fp32 absmax scale per 256-element block (bitsandbytes-style),
dequantized to fp32 inside the update, re-quantized after. The quantization
error enters the *moments* (statistics), not the weights, so there is no
error-feedback requirement — confirmed by the convergence smoke test.

No optax dependency: the framework owns its optimizer (scope requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # 'float32' | 'int8'
    schedule: str = "cosine"          # 'cosine' | 'constant' | 'wsd'
    final_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Warmup + {cosine | constant | warmup-stable-decay} schedule.

    WSD (minicpm-2b's schedule, arXiv:2404.06395): stable at peak for 80% of
    steps then linear decay to final_lr_frac.
    """
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.final_lr_frac + (1 - cfg.final_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        stable_frac = 0.8
        decay = jnp.where(
            t < stable_frac, 1.0,
            1.0 - (1 - cfg.final_lr_frac) * (t - stable_frac) / (1 - stable_frac))
    else:
        decay = jnp.ones_like(t)
    return cfg.learning_rate * warm * decay


# ---------------------------------------------------------------------------
# blockwise int8 moment quantization
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array) -> dict:
    """Blockwise int8 along the LAST dim, shape-preserving.

    (..., D) -> q (..., D/256, 256) + scale (..., D/256, 1). Keeping the
    leading dims intact lets the optimizer state inherit the parameter's
    GSPMD sharding — a flattening reshape here forces XLA to re-gather the
    full fp32 gradient per step (EXPERIMENTS.md §Perf iteration 3: ~4 TB/chip
    of involuntary all-reduce on llama3-405b). Tensors whose last dim does
    not divide 256 (norm vectors, biases — replicated anyway) fall back to a
    padded single-row layout.
    """
    x32 = x.astype(jnp.float32)
    last = x.shape[-1] if x.ndim else 1
    if x.ndim and last % _BLOCK == 0:
        blocks = x32.reshape(*x.shape[:-1], last // _BLOCK, _BLOCK)
    else:
        flat = x32.reshape(-1)
        pad = (-flat.shape[0]) % _BLOCK
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        blocks = flat.reshape(1, -1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(packed: dict, shape, size: int) -> jax.Array:
    vals = packed["q"].astype(jnp.float32) * packed["scale"]
    if vals.size == size and vals.ndim == len(shape) + 1:
        return vals.reshape(shape)          # blockwise-last-dim layout
    return vals.reshape(-1)[:size].reshape(shape)   # padded fallback


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def _moment_init(p: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(jnp.zeros_like(p, jnp.float32))
    return jnp.zeros_like(p, jnp.float32)


def adamw_init(params, cfg: AdamWConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype), params),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: dict, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    int8 = cfg.moment_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if int8:
            m = _dequantize(m, p.shape, p.size)
            v = _dequantize(v, p.shape, p.size)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if int8:
            m, v = _quantize(m), _quantize(v)
        return new_p, m, v

    is_packed = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"]) if int8 else jax.tree.leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if int8 else jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
